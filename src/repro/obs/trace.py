"""Structured tracing: nested spans over the solve path, off by default.

A `Span` is a named, timed interval with attributes and point-in-time
events; spans nest via a per-thread stack so `operator.solve` opened
inside `serving.batch` records the right parent without any plumbing.
The taxonomy of span/event names lives in docs/observability.md.

Two disciplines carried over from the rest of the repo:

* **Injected clocks** — a `Tracer` takes `clock=` at construction
  (default `time.perf_counter`, the same timebase `SolveService._clock`
  uses) and never calls a clock the caller didn't hand it, matching the
  micro-batcher's testable-time rule.  Tests drive traces with fake
  clocks and assert exact durations.
* **No-op unless enabled** — the module-level `span()`/`event()` helpers
  that production code calls consult one global; when no tracer is
  installed they return a shared do-nothing span.  The hot path pays one
  global read + one method call, ≤5% of a cached solve (enforced by
  tests/test_thread_safety.py).  Enable explicitly via `obs.enable()` or
  by setting `REPRO_TRACE` in the environment before import.

Cross-thread intervals that cannot use a `with` block (a request's queue
wait starts on the submitting thread and ends on the batch thread) are
recorded retroactively with `record_span(name, t_start, t_end, parent=)`.

When a tracer is built with `annotate_jax=True`, each span also enters a
`jax.profiler.TraceAnnotation` of the same name so repro spans line up
with XLA events in an xplane profile; the import is lazy and failures
degrade to plain tracing.
"""
from __future__ import annotations

import itertools
import os
import threading
import time

__all__ = ["Span", "Tracer", "enable", "disable", "enabled", "get_tracer",
           "span", "event", "record_span", "NULL_SPAN"]


class Span:
    """One timed interval. Created by `Tracer.span(...)`; use as a
    context manager. Ids/parenting are assigned at `__enter__` (that is
    when the per-thread stack position is known)."""

    __slots__ = ("name", "attrs", "events", "span_id", "parent_id",
                 "t_start", "t_end", "tid", "_tracer", "_jax_ctx")

    def __init__(self, name, attrs, tracer):
        self.name = name
        self.attrs = dict(attrs)
        self.events = []
        self.span_id = None
        self.parent_id = None
        self.t_start = None
        self.t_end = None
        self.tid = None
        self._tracer = tracer
        self._jax_ctx = None

    def __enter__(self):
        tr = self._tracer
        stack = tr._stack()
        self.parent_id = stack[-1].span_id if stack else None
        self.tid = threading.get_ident()
        with tr._lock:
            self.span_id = next(tr._ids)
            tr._open[self.span_id] = self
        stack.append(self)
        if tr.annotate_jax:
            self._jax_ctx = tr._jax_annotation(self.name)
            if self._jax_ctx is not None:
                self._jax_ctx.__enter__()
        self.t_start = tr.clock()
        return self

    def __exit__(self, exc_type, exc, tb):
        tr = self._tracer
        self.t_end = tr.clock()
        if self._jax_ctx is not None:
            self._jax_ctx.__exit__(exc_type, exc, tb)
            self._jax_ctx = None
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        elif self in stack:          # exited out of order; keep nesting sane
            stack.remove(self)
        with tr._lock:
            tr._open.pop(self.span_id, None)
            if len(tr._finished) < tr.max_spans:
                tr._finished.append(self)
        return False

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def event(self, name: str, **attrs) -> None:
        """Point-in-time marker inside this span."""
        self.events.append((name, self._tracer.clock(), attrs))

    @property
    def duration(self) -> float:
        if self.t_start is None or self.t_end is None:
            return float("nan")
        return self.t_end - self.t_start

    def __repr__(self):
        return (f"Span({self.name!r}, id={self.span_id}, "
                f"parent={self.parent_id}, dur={self.duration:.6f})")


class _NullSpan:
    """Shared do-nothing span returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self

    def event(self, name=None, **attrs):
        pass


NULL_SPAN = _NullSpan()


class Tracer:
    """Collects finished spans (bounded by `max_spans`) plus orphan
    events that fired outside any span. Thread-safe; span nesting is
    tracked per thread."""

    def __init__(self, clock=time.perf_counter, max_spans: int = 200_000,
                 annotate_jax: bool = False):
        self.clock = clock
        self.max_spans = int(max_spans)
        self.annotate_jax = bool(annotate_jax)
        self._lock = threading.Lock()
        self._ids = itertools.count(1)
        self._local = threading.local()
        self._open: dict = {}
        self._finished: list = []
        self._orphans: list = []
        self._annot_cls = None       # lazy jax.profiler.TraceAnnotation

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _jax_annotation(self, name):
        if self._annot_cls is None:
            try:
                from jax.profiler import TraceAnnotation
                self._annot_cls = TraceAnnotation
            except Exception:
                self._annot_cls = False
        return self._annot_cls(name) if self._annot_cls else None

    def span(self, name: str, **attrs) -> Span:
        return Span(name, attrs, self)

    def event(self, name: str, **attrs) -> None:
        """Attach to the current span, else record as an orphan."""
        stack = self._stack()
        if stack:
            stack[-1].event(name, **attrs)
            return
        with self._lock:
            if len(self._orphans) < self.max_spans:
                self._orphans.append(
                    (name, self.clock(), attrs, threading.get_ident()))

    def record_span(self, name: str, t_start: float, t_end: float, *,
                    parent=None, tid=None, **attrs) -> Span:
        """Retroactively record an interval measured elsewhere (module
        doc: cross-thread queue waits). `parent` is a Span or span id."""
        sp = Span(name, attrs, self)
        sp.t_start = float(t_start)
        sp.t_end = float(t_end)
        if isinstance(parent, Span):
            parent = parent.span_id
        elif not isinstance(parent, (int, type(None))):
            parent = None            # e.g. NULL_SPAN from a mid-flight enable
        sp.parent_id = parent
        sp.tid = threading.get_ident() if tid is None else tid
        with self._lock:
            sp.span_id = next(self._ids)
            if len(self._finished) < self.max_spans:
                self._finished.append(sp)
        return sp

    def current_span(self):
        stack = self._stack()
        return stack[-1] if stack else None

    def spans(self) -> list:
        with self._lock:
            return list(self._finished)

    def orphan_events(self) -> list:
        with self._lock:
            return list(self._orphans)

    def open_spans(self) -> list:
        """Spans entered but not yet exited — must be empty at export
        time for a trace to validate."""
        with self._lock:
            return list(self._open.values())

    def reset(self) -> None:
        with self._lock:
            self._finished.clear()
            self._orphans.clear()


# ----------------------------------------------------------------------
# process-wide default tracer (module doc: one global read when disabled)

_TRACER: Tracer | None = None


def enable(tracer: Tracer | None = None, **kw) -> Tracer:
    """Install `tracer` (or a fresh `Tracer(**kw)`) as the process-wide
    default and return it."""
    global _TRACER
    _TRACER = tracer if tracer is not None else Tracer(**kw)
    return _TRACER


def disable() -> Tracer | None:
    """Uninstall and return the active tracer (None if none was)."""
    global _TRACER
    tr, _TRACER = _TRACER, None
    return tr


def enabled() -> bool:
    return _TRACER is not None


def get_tracer() -> Tracer | None:
    return _TRACER


def span(name: str, **attrs):
    """`with obs.span("operator.solve", n=n):` — NULL_SPAN when off."""
    tr = _TRACER
    if tr is None:
        return NULL_SPAN
    return tr.span(name, **attrs)


def event(name: str, **attrs) -> None:
    tr = _TRACER
    if tr is not None:
        tr.event(name, **attrs)


def record_span(name: str, t_start: float, t_end: float, *,
                parent=None, **attrs):
    tr = _TRACER
    if tr is None:
        return NULL_SPAN
    return tr.record_span(name, t_start, t_end, parent=parent, **attrs)


if os.environ.get("REPRO_TRACE", "") not in ("", "0"):
    enable()

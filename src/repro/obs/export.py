"""Exporters + validators for traces and metrics.

Three output formats (docs/observability.md shows each):

* **Chrome trace-event JSON** — load in `chrome://tracing` or Perfetto.
  Spans become `ph:"X"` complete events (ts/dur in µs, rebased to the
  earliest span so traces start at 0), span events and orphan events
  become `ph:"i"` instants, and `args` carries span_id/parent_id plus
  the span attributes so the nesting is recoverable programmatically
  (Chrome's own nesting is per-tid stack-based; cross-thread parents —
  a queue span parented under another thread's batch span — survive in
  `args.parent_id` only, and `validate_chrome_trace` deliberately does
  NOT require child intervals inside the parent's for that reason).
* **JSON-lines event log** — one object per span/event/metrics-snapshot,
  grep- and pandas-friendly.
* **Prometheus text exposition** — every instrument of one or more
  `MetricsRegistry` sources as `<prefix>_<name>` families; histograms
  expand to cumulative `_bucket{le=...}` + `_sum`/`_count`, text
  instruments to `<name>_info{value="..."} 1`.  Multiple sources with
  the same prefix (per-entry operator registries) merge under one
  HELP/TYPE header, distinguished by caller-supplied labels.

The validators are what CI's observability-smoke job runs: a trace must
have every span closed and every parent id resolvable; a metrics page
must be line-by-line well-formed with TYPE headers preceding samples.
"""
from __future__ import annotations

import json
import math
import re

__all__ = ["chrome_trace", "write_chrome_trace", "validate_chrome_trace",
           "write_jsonl", "prometheus_text", "validate_prometheus_text"]


def _json_safe(v):
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else repr(v)
    return str(v)


def _args(attrs: dict) -> dict:
    return {str(k): _json_safe(v) for k, v in attrs.items()}


# ----------------------------------------------------------------------
# Chrome trace-event format

def chrome_trace(tracer) -> dict:
    """Render a Tracer's finished spans/events as a trace-event document."""
    spans = tracer.spans()
    orphans = tracer.orphan_events()
    t0 = min(
        [sp.t_start for sp in spans if sp.t_start is not None]
        + [t for _, t, _, _ in orphans],
        default=0.0)

    def us(t):
        return (t - t0) * 1e6

    events = []
    for sp in spans:
        cat = sp.name.split(".", 1)[0]
        events.append({
            "name": sp.name, "cat": cat, "ph": "X",
            "ts": us(sp.t_start), "dur": max(0.0, us(sp.t_end) - us(sp.t_start)),
            "pid": 1, "tid": sp.tid or 0,
            "args": {"span_id": sp.span_id, "parent_id": sp.parent_id,
                     **_args(sp.attrs)},
        })
        for name, t, attrs in sp.events:
            events.append({
                "name": name, "cat": cat, "ph": "i", "s": "t",
                "ts": us(t), "pid": 1, "tid": sp.tid or 0,
                "args": {"span_id": sp.span_id, **_args(attrs)},
            })
    for name, t, attrs, tid in orphans:
        events.append({
            "name": name, "cat": name.split(".", 1)[0], "ph": "i", "s": "g",
            "ts": us(t), "pid": 1, "tid": tid,
            "args": _args(attrs),
        })
    events.sort(key=lambda e: e["ts"])
    return {
        "traceEvents": events,
        "displayTimeUnit": "ms",
        "metadata": {
            "clock": "perf_counter",
            "open_spans": [sp.name for sp in tracer.open_spans()],
        },
    }


def write_chrome_trace(path, tracer) -> dict:
    doc = chrome_trace(tracer)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
    return doc


def validate_chrome_trace(doc) -> list:
    """Schema check; returns a list of problem strings (empty == valid)."""
    problems = []
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        return ["document is not a dict with a traceEvents list"]
    open_spans = (doc.get("metadata") or {}).get("open_spans", [])
    if open_spans:
        problems.append(f"unclosed spans at export: {open_spans}")
    span_ids = set()
    parents = []
    for i, ev in enumerate(doc["traceEvents"]):
        where = f"event[{i}]"
        if not isinstance(ev, dict):
            problems.append(f"{where}: not an object")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            problems.append(f"{where}: missing name")
        if ev.get("ph") not in ("X", "i", "M"):
            problems.append(f"{where}: bad ph {ev.get('ph')!r}")
        if not isinstance(ev.get("ts"), (int, float)):
            problems.append(f"{where}: non-numeric ts")
        if ev.get("ph") == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                problems.append(f"{where} ({ev.get('name')}): bad dur {dur!r}")
            sid = (ev.get("args") or {}).get("span_id")
            if sid is None:
                problems.append(f"{where} ({ev.get('name')}): no span_id")
            elif sid in span_ids:
                problems.append(f"{where}: duplicate span_id {sid}")
            else:
                span_ids.add(sid)
            pid = (ev.get("args") or {}).get("parent_id")
            if pid is not None:
                parents.append((where, ev.get("name"), pid))
    for where, name, pid in parents:
        if pid not in span_ids:
            problems.append(
                f"{where} ({name}): parent_id {pid} does not resolve")
    return problems


# ----------------------------------------------------------------------
# JSON-lines event log

def write_jsonl(path, tracer=None, registries=()) -> int:
    """One JSON object per line: spans, orphan events, then one metrics
    snapshot per registry. Returns the number of lines written."""
    lines = []
    if tracer is not None:
        for sp in tracer.spans():
            lines.append({
                "type": "span", "name": sp.name, "span_id": sp.span_id,
                "parent_id": sp.parent_id, "t_start": sp.t_start,
                "t_end": sp.t_end, "tid": sp.tid, "attrs": _args(sp.attrs),
                "events": [{"name": n, "t": t, "attrs": _args(a)}
                           for n, t, a in sp.events],
            })
        for name, t, attrs, tid in tracer.orphan_events():
            lines.append({"type": "event", "name": name, "t": t,
                          "tid": tid, "attrs": _args(attrs)})
    for reg in registries:
        lines.append({"type": "metrics", "prefix": reg.prefix,
                      "snapshot": reg.snapshot()})
    with open(path, "w") as fh:
        for obj in lines:
            fh.write(json.dumps(obj, default=str) + "\n")
    return len(lines)


# ----------------------------------------------------------------------
# Prometheus text exposition

_NAME_OK = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_OK = re.compile(r"[a-zA-Z_][a-zA-Z0-9_]*$")


def _metric_name(s: str) -> str:
    s = re.sub(r"[^a-zA-Z0-9_:]", "_", str(s))
    return s if _NAME_OK.match(s) else "_" + s


def _label_str(pairs) -> str:
    parts = []
    for k, v in pairs:
        k = re.sub(r"[^a-zA-Z0-9_]", "_", str(k))
        v = str(v).replace("\\", r"\\").replace('"', r"\"")
        v = v.replace("\n", r"\n")
        parts.append(f'{k}="{v}"')
    return "{" + ",".join(parts) + "}" if parts else ""


def _num(v) -> str:
    v = float(v)
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(v) if isinstance(v, float) else str(v)


def _family_lines(inst, extra, out):
    """Sample lines for one instrument under `extra` source labels."""
    if inst.kind == "histogram":
        for key, st in inst.series().items():
            base = list(extra) + list(key)
            cum = 0
            for bound, n in zip(list(inst.bounds) + [float("inf")],
                                st["buckets"]):
                cum += n
                le = "+Inf" if math.isinf(bound) else _num(bound)
                out.append(("_bucket",
                            _label_str(base + [("le", le)]), cum))
            out.append(("_sum", _label_str(base), st["sum"]))
            out.append(("_count", _label_str(base), st["count"]))
    elif inst.kind == "text":
        for key, s in inst.series().items():
            out.append(("_info",
                        _label_str(list(extra) + list(key) + [("value", s)]),
                        1))
    else:
        for key, v in inst.series().items():
            out.append(("", _label_str(list(extra) + list(key)), v))


def prometheus_text(*sources) -> str:
    """Render registries as a Prometheus text page.

    Each source is a `MetricsRegistry` or a `(registry, labels_dict)`
    pair; the labels are attached to every sample from that source
    (module doc: how per-entry operator registries merge).
    """
    families: dict = {}       # full name -> (kind, help, [(suffix, labels, value)])
    for src in sources:
        reg, extra = (src if isinstance(src, tuple) else (src, {}))
        extra = tuple(sorted(extra.items()))
        for inst in reg.collect():
            full = _metric_name(f"{reg.prefix}_{inst.name}")
            kind = "gauge" if inst.kind == "text" else inst.kind
            fam = families.setdefault(full, (kind, inst.help, []))
            _family_lines(inst, extra, fam[2])
    chunks = []
    for full, (kind, help, samples) in families.items():
        if help:
            chunks.append(f"# HELP {full} {help}")
        chunks.append(f"# TYPE {full} {kind}")
        for suffix, labels, value in samples:
            chunks.append(f"{full}{suffix}{labels} "
                          f"{_num(value) if not isinstance(value, int) else value}")
    return "\n".join(chunks) + "\n"


_SAMPLE_RE = re.compile(
    r"^([a-zA-Z_:][a-zA-Z0-9_:]*)"              # metric name
    r'(\{[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*"'
    r'(?:,[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\]|\\.)*")*\})?'
    r" (NaN|[+-]?Inf|[+-]?[0-9.eE+-]+)$")
_SUFFIX_RE = re.compile(r"(_bucket|_sum|_count|_info)$")


def validate_prometheus_text(text: str) -> list:
    """Line-by-line exposition-format check; returns problem strings."""
    problems = []
    typed: set = set()
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("#"):
            m = re.match(r"^# (HELP|TYPE) ([a-zA-Z_:][a-zA-Z0-9_:]*) .+$",
                         line)
            if not m:
                problems.append(f"line {ln}: malformed comment: {line!r}")
            elif m.group(1) == "TYPE":
                if m.group(2) in typed:
                    problems.append(
                        f"line {ln}: duplicate TYPE for {m.group(2)}")
                typed.add(m.group(2))
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            problems.append(f"line {ln}: malformed sample: {line!r}")
            continue
        name = m.group(1)
        base = _SUFFIX_RE.sub("", name)
        if name not in typed and base not in typed:
            problems.append(f"line {ln}: sample before TYPE: {name}")
        try:
            float(m.group(3))
        except ValueError:
            problems.append(f"line {ln}: bad value {m.group(3)!r}")
    return problems

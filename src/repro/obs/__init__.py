"""repro.obs — unified observability layer (docs/observability.md).

Four pieces, all zero-dependency and off-by-default:

* `trace`   — structured nested spans over the solve path; no-op unless
  enabled (`obs.enable()` / `REPRO_TRACE=1`).
* `metrics` — the counters/gauges/histograms registry every stats plane
  (`OperatorStats`, `ServiceStats`, registry lifecycle counters,
  portfolio tune counters) is a view over.
* `profile` — the per-step schedule profiler + `ProfilingEngine` wrapper
  (collective vs. compute split on the sharded path); feeds
  `CostModel.calibrate`.
* `export`  — Chrome trace-event, JSON-lines, and Prometheus text
  exporters plus the validators CI runs.

Quick trace of a solve::

    from repro import obs
    obs.enable()
    op.solve(b)
    obs.export.write_chrome_trace("solve.trace.json", obs.get_tracer())

`profile` is loaded lazily: it needs `repro.solver`, which itself
traces through this package — eager import here would be a cycle.
"""
from __future__ import annotations

import importlib

from . import export, metrics, trace
from .metrics import MetricsRegistry, default_registry
from .trace import (NULL_SPAN, Span, Tracer, disable, enable, enabled,
                    event, get_tracer, record_span, span)

__all__ = ["trace", "metrics", "export", "profile",
           "Span", "Tracer", "enable", "disable", "enabled", "get_tracer",
           "span", "event", "record_span", "NULL_SPAN",
           "MetricsRegistry", "default_registry"]


def __getattr__(name):
    if name == "profile":
        mod = importlib.import_module(".profile", __name__)
        globals()["profile"] = mod
        return mod
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

"""Metrics registry: counters, gauges, histograms, and info text.

The single backing store for every stats plane in the repo:
`OperatorStats` (solver/operator.py), `ServiceStats` and the registry
lifecycle counters (serving/), and the portfolio's tune/measure-note
counters are all *views* over instruments held in a `MetricsRegistry` —
their `to_dict()`/`snapshot()` read the instruments, nothing is counted
twice (docs/observability.md).

Thread-safety follows `OperatorStats`' discipline: ONE re-entrant lock
per registry, shared by every instrument it owns, so a multi-instrument
commit (`record_solve` bumps solves + total_solve_ms + ... in one
acquisition) is atomic — `solves` and `total_solve_ms` always describe
the same set of solves.  Reads of a single instrument are committed
values; whole-registry snapshots take the lock once.

Instruments support Prometheus-style labels (`counter.inc(reason="width")`)
stored as sorted key/value tuples, and histograms carry FIXED bucket
boundaries plus an optional bounded sample reservoir for nearest-rank
percentiles (the exact formula `ServiceStats` has always used).
Exporters live in `repro.obs.export` (Prometheus text, JSON).
"""
from __future__ import annotations

import threading

__all__ = ["MetricsRegistry", "Counter", "Gauge", "Text", "Histogram",
           "default_registry", "nearest_rank_percentile",
           "DEFAULT_MS_BUCKETS"]

# latency-style boundaries (milliseconds), upper-inclusive like
# Prometheus `le`; the overflow bucket is implicit (+Inf)
DEFAULT_MS_BUCKETS = (0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0,
                      50.0, 100.0, 250.0, 1000.0, 5000.0)


def nearest_rank_percentile(samples, q: float) -> float:
    """Nearest-rank percentile of a sequence (NaN when empty) — the ONE
    formula the serving stats plane has used since PR 8."""
    if not samples:
        return float("nan")
    s = sorted(samples)
    rank = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return float(s[rank])


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


class _Instrument:
    """Shared shape: named, labeled series, registry-owned lock."""

    kind = "abstract"

    def __init__(self, name: str, help: str, lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: dict = {}

    def series(self) -> dict:
        """Copy of label-tuple -> value (histograms: -> state dict)."""
        with self._lock:
            return dict(self._series)

    def labels(self) -> list:
        with self._lock:
            return list(self._series)


class Counter(_Instrument):
    """Monotonic counter (int or float increments)."""

    kind = "counter"

    def inc(self, n=1, **labels) -> None:
        k = _label_key(labels)
        with self._lock:
            self._series[k] = self._series.get(k, 0) + n

    def value(self, **labels):
        with self._lock:
            return self._series.get(_label_key(labels), 0)

    def total(self):
        """Sum over every labeled series."""
        with self._lock:
            return sum(self._series.values())


class Gauge(_Instrument):
    """Last-write-wins value; `default` is what value() reads before any
    set (0.0 unless configured, e.g. NaN for last_residual)."""

    kind = "gauge"

    def __init__(self, name, help, lock, default: float = 0.0):
        super().__init__(name, help, lock)
        self.default = default

    def set(self, v, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = v

    def add(self, v, **labels) -> None:
        k = _label_key(labels)
        with self._lock:
            self._series[k] = self._series.get(k, self.default) + v

    def value(self, **labels):
        with self._lock:
            return self._series.get(_label_key(labels), self.default)


class Text(_Instrument):
    """String-valued info instrument (cache_source, last_fallback, ...).
    Prometheus export renders it as `<name>_info{value="..."} 1`."""

    kind = "text"

    def set(self, s: str, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = str(s)

    def value(self, **labels) -> str:
        with self._lock:
            return self._series.get(_label_key(labels), "")


class Histogram(_Instrument):
    """Fixed-boundary histogram + optional bounded sample reservoir.

    Per labeled series: bucket counts (one per boundary, upper-inclusive,
    plus the implicit +Inf overflow), running sum and count, and — when
    `reservoir > 0` — the first `reservoir` raw samples for nearest-rank
    percentiles.  The reservoir STOPS admitting at capacity (it is a
    bounded memory guarantee, not a sliding window), exactly like the
    latency lists `ServiceStats` kept before this module existed.
    """

    kind = "histogram"

    def __init__(self, name, help, lock, bounds=DEFAULT_MS_BUCKETS,
                 reservoir: int = 0):
        super().__init__(name, help, lock)
        self.bounds = tuple(float(b) for b in bounds)
        if list(self.bounds) != sorted(self.bounds):
            raise ValueError(f"histogram bounds must be sorted: {bounds}")
        self.reservoir = int(reservoir)

    def _state(self, k):
        st = self._series.get(k)
        if st is None:
            st = self._series[k] = {
                "buckets": [0] * (len(self.bounds) + 1),
                "sum": 0.0, "count": 0,
                "samples": [] if self.reservoir else None}
        return st

    def observe(self, v: float, **labels) -> None:
        v = float(v)
        k = _label_key(labels)
        with self._lock:
            st = self._state(k)
            i = 0
            for i, b in enumerate(self.bounds):
                if v <= b:
                    break
            else:
                i = len(self.bounds)
            st["buckets"][i] += 1
            st["sum"] += v
            st["count"] += 1
            if st["samples"] is not None and \
                    len(st["samples"]) < self.reservoir:
                st["samples"].append(v)

    def count(self, **labels) -> int:
        with self._lock:
            st = self._series.get(_label_key(labels))
            return 0 if st is None else st["count"]

    def sum(self, **labels) -> float:
        with self._lock:
            st = self._series.get(_label_key(labels))
            return 0.0 if st is None else st["sum"]

    def samples(self, **labels) -> list:
        with self._lock:
            st = self._series.get(_label_key(labels))
            return [] if st is None or st["samples"] is None \
                else list(st["samples"])

    def percentile(self, q: float, **labels) -> float:
        """Nearest-rank percentile over the reservoir (NaN when empty or
        reservoir-less)."""
        return nearest_rank_percentile(self.samples(**labels), q)

    def buckets(self, **labels) -> dict:
        """{upper_bound: count} (non-cumulative), +Inf as float('inf')."""
        with self._lock:
            st = self._series.get(_label_key(labels))
            counts = [0] * (len(self.bounds) + 1) if st is None \
                else list(st["buckets"])
        edges = list(self.bounds) + [float("inf")]
        return dict(zip(edges, counts))


class MetricsRegistry:
    """Named instruments behind one shared lock (module doc).

    `prefix` namespaces the exported metric names ("repro_operator", ...);
    instrument names themselves stay short snake_case ("solves").
    get-or-create accessors return the existing instrument when the name
    is already registered (and raise if it was registered as another
    kind), so independent views can share a backing series safely.
    """

    def __init__(self, prefix: str = "repro"):
        self.prefix = prefix
        self._lock = threading.RLock()
        self._metrics: dict = {}

    @property
    def lock(self):
        """The shared lock, for multi-instrument atomic commits."""
        return self._lock

    def _get_or_create(self, cls, name, help, **kw):
        with self._lock:
            inst = self._metrics.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise TypeError(
                        f"metric {name!r} already registered as "
                        f"{inst.kind}, not {cls.kind}")
                return inst
            inst = self._metrics[name] = cls(name, help, self._lock, **kw)
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "",
              default: float = 0.0) -> Gauge:
        return self._get_or_create(Gauge, name, help, default=default)

    def text(self, name: str, help: str = "") -> Text:
        return self._get_or_create(Text, name, help)

    def histogram(self, name: str, help: str = "",
                  bounds=DEFAULT_MS_BUCKETS,
                  reservoir: int = 0) -> Histogram:
        return self._get_or_create(Histogram, name, help, bounds=bounds,
                                   reservoir=reservoir)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def collect(self) -> list:
        """Every registered instrument (stable registration order)."""
        with self._lock:
            return list(self._metrics.values())

    def snapshot(self) -> dict:
        """JSON-friendly dump: name -> {kind, series} with label tuples
        rendered as 'k=v,k2=v2' strings ('' for the unlabeled series)."""
        out = {}
        with self._lock:
            for name, inst in self._metrics.items():
                series = {
                    ",".join(f"{k}={v}" for k, v in key): val
                    for key, val in inst.series().items()}
                out[name] = {"kind": inst.kind, "series": series}
        return out


_DEFAULT = MetricsRegistry(prefix="repro")


def default_registry() -> MetricsRegistry:
    """The process-wide registry (portfolio counters and other module-level
    producers land here; per-object stats planes own their own)."""
    return _DEFAULT

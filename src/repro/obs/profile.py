"""Per-step schedule profiler: where does a solve's wall time go?

The paper's argument is about barriers and serial regions; this module
measures them.  `profile_schedule` executes a width-bucketed
`LevelSchedule` ONE STEP AT A TIME — the same `_step_body` the scan and
unrolled engines run, jitted once and reused for every step since all
steps share the tile shapes — and records a min-over-reps wall time per
step.  On the sharded path each step runs twice under `shard_map`: once
with the real per-step `all_gather` family and once with an identity
gather shim (same FLOPs, no collective — the numerics of that pass are
garbage and are discarded), so `collective_ms` = full − compute is the
per-step barrier cost the transformation exists to amortize.

The result is a `ScheduleProfile`: per-step times, the collective/compute
split, padded-FLOP utilization per width bucket, step-time histograms,
and critical-path share.  It is the measurement the analytic CostModel's
constants should come from — `CostModel.calibrate(profile)`
(repro.core.portfolio) fits them to one.

`ProfilingEngine` wraps any registered engine with this loop behind the
standard Engine protocol (opt-in: per-step dispatch costs real overhead,
this is a measurement tool, not a serving path), exposing `last_profile`
after each solve.  `profile_operator` profiles a built
`TriangularOperator`'s main schedule with the operator's own preamble
applied, routing mesh/axis from a sharded default engine.

Clocks are injected (`clock=time.perf_counter` by default), matching the
tracing core's discipline.  `core.faults.slow_step` patches this
module's `_STEP_FAULT` seam to inject a stall into one step — the chaos
test asserts the profile localizes it.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from .metrics import DEFAULT_MS_BUCKETS
from . import trace as _trace

__all__ = ["ScheduleProfile", "profile_schedule", "profile_operator",
           "ProfilingEngine"]

# (step_idx, seconds) | None — patched by core.faults.slow_step to stall
# one step of every *timed* pass (warmup runs stay clean)
_STEP_FAULT = None


def _fire_step_fault(s: int) -> None:
    f = _STEP_FAULT
    if f is not None and f[0] == s:
        time.sleep(f[1])


@dataclasses.dataclass
class ScheduleProfile:
    """One profiled execution of a schedule (module doc).

    `step_ms` is min-over-reps per step; `collective_ms` is present only
    for sharded profiles (None otherwise); the flop/byte columns are the
    schedule the run actually executed (lane-padded on the sharded path).
    """

    engine: str
    num_steps: int
    reps: int
    step_ms: np.ndarray
    collective_ms: np.ndarray | None
    step_padded_flops: np.ndarray
    step_real_flops: np.ndarray
    step_bytes: np.ndarray
    width_buckets: list

    @property
    def compute_ms(self):
        """Per-step compute component (collective subtracted, clamped at
        0); None when the profile has no collective split."""
        if self.collective_ms is None:
            return None
        return np.maximum(self.step_ms - self.collective_ms, 0.0)

    def total_ms(self) -> float:
        return float(self.step_ms.sum())

    def critical_path_share(self) -> float:
        """Share of total time the serialized step floor (S x fastest
        step) accounts for: 1.0 = perfectly uniform steps, low values =
        a few straggler steps dominate."""
        tot = float(self.step_ms.sum())
        if not self.num_steps or tot <= 0:
            return float("nan")
        return float(self.num_steps * self.step_ms.min() / tot)

    def utilization(self) -> float:
        """Real / padded FLOPs over the whole schedule."""
        p = sum(b["padded_flops"] for b in self.width_buckets)
        r = sum(b["real_flops"] for b in self.width_buckets)
        return r / p if p else 0.0

    def slowest_steps(self, k: int = 5) -> list:
        order = np.argsort(self.step_ms, kind="stable")[::-1]
        return [int(i) for i in order[:k]]

    def step_histogram(self, bounds=DEFAULT_MS_BUCKETS) -> dict:
        """Step-time histogram over fixed upper-inclusive bounds (ms);
        the final count is the +Inf overflow."""
        counts = [0] * (len(bounds) + 1)
        for v in self.step_ms:
            i = len(bounds)
            for j, b in enumerate(bounds):
                if v <= b:
                    i = j
                    break
            counts[i] += 1
        return {"bounds": list(bounds), "counts": counts}

    def to_dict(self) -> dict:
        return {
            "engine": self.engine, "num_steps": self.num_steps,
            "reps": self.reps,
            "total_ms": self.total_ms(),
            "critical_path_share": self.critical_path_share(),
            "utilization": self.utilization(),
            "step_ms": [float(v) for v in self.step_ms],
            "collective_ms": (None if self.collective_ms is None else
                              [float(v) for v in self.collective_ms]),
            "step_padded_flops": [int(v) for v in self.step_padded_flops],
            "step_real_flops": [int(v) for v in self.step_real_flops],
            "step_bytes": [float(v) for v in self.step_bytes],
            "width_buckets": list(self.width_buckets),
            "step_histogram": self.step_histogram(),
            "slowest_steps": self.slowest_steps(),
        }


def _schedule_columns(sched):
    """(per-step padded flops, per-step real flops, per-step bytes,
    width buckets) for the schedule as executed."""
    S = sched.num_steps
    ppf = 0
    rf = np.zeros(S, dtype=np.int64)
    buckets = []
    for g in sched.groups:
        s_, c_, d_ = g.dep_idx.shape
        padded = 2 * s_ * c_ * d_ + s_ * c_
        real = int(2 * (g.dep_coef != 0).sum() + g.is_final.sum())
        ppf += 2 * c_ * d_ + c_
        rf += (2 * (g.dep_coef != 0).sum(axis=(1, 2))
               + g.is_final.sum(axis=1))
        buckets.append({
            "width": int(g.width), "lanes": int(c_),
            "padded_flops": int(padded), "real_flops": real,
            "utilization": real / padded if padded else 0.0})
    pf = np.full(S, ppf, dtype=np.int64)
    sb = np.full(S, sched.memory_bytes() / max(1, S), dtype=np.float64)
    return pf, rf, sb, buckets


def _profile_and_solve(host, c, *, reps, warmup, clock, mesh, axis):
    """Core loop: returns (ScheduleProfile, x) for host LevelSchedule."""
    import jax
    import jax.numpy as jnp
    from ..solver.levelset import _init_state, _step_body, to_device

    c = jnp.asarray(c, dtype=jnp.empty(0, dtype=host.dtype).dtype)
    if mesh is None:
        exec_sched = host
        ds = to_device(host)
        step_fns = {"full": jax.jit(_step_body)}
        label = "stepwise"
    else:
        from jax.sharding import PartitionSpec as P
        from ..solver.distributed import (_gather, _padded_schedule,
                                          _step_update, require_axis,
                                          shard_map_compat)
        require_axis(mesh, axis)
        exec_sched = _padded_schedule(host, mesh.shape[axis])
        with jax.ensure_compile_time_eval():
            ds = to_device(exec_sched)
        # specs for ONE step's slices: stacked (S, C) leaves arrive as
        # (C,) lane vectors, (S, C, D) as (C, D) tiles — lanes sharded,
        # x/carry/c_pad replicated, exactly as in lower_sharded
        step_specs = tuple(
            tuple(P(axis) if l.ndim == 2 else P(axis, None) for l in g)
            for g in ds.leaves())

        def make_step(gather):
            def body(x, carry, c_pad, sg):
                return _step_update(x, carry, c_pad, sg,
                                    n_carry=ds.n_carry, axis=axis,
                                    gather=gather)
            return jax.jit(shard_map_compat(
                body, mesh, (P(), P(), P(), step_specs), (P(), P())))

        # the identity-gather pass keeps each device's partial updates
        # local: same per-step FLOPs, no collective, unusable numerics —
        # timed and discarded (module doc)
        step_fns = {"full": make_step(_gather),
                    "compute": make_step(lambda v, ax: v)}
        label = "sharded"

    leaves = ds.leaves()
    S = ds.num_steps
    per_step = [tuple(tuple(l[s] for l in g) for g in leaves)
                for s in range(S)]

    def run(record, step_fn):
        x, carry, c_pad = _init_state(ds.n, ds.n_carry, c)
        for s, sg in enumerate(per_step):
            t0 = clock()
            if record is not None:
                _fire_step_fault(s)     # stall INSIDE the timed window
            x, carry = step_fn(x, carry, c_pad, sg)
            jax.block_until_ready((x, carry))
            if record is not None:
                record[s] = min(record[s], clock() - t0)
        return x[:ds.n]

    with _trace.span("profile.schedule", steps=S, engine=label,
                     reps=reps) as sp:
        timings = {}
        x = c[:ds.n] * 0 if S == 0 else None
        for kind, step_fn in step_fns.items():
            for _ in range(max(0, warmup)):
                run(None, step_fn)
            rec = np.full(S, np.inf)
            for _ in range(max(1, reps)):
                out = run(rec, step_fn)
                if kind == "full":
                    x = out
            timings[kind] = np.where(np.isfinite(rec), rec, 0.0)

        step_ms = timings["full"] * 1e3
        collective_ms = None
        if "compute" in timings:
            collective_ms = np.maximum(
                step_ms - timings["compute"] * 1e3, 0.0)
        pf, rf, sb, buckets = _schedule_columns(exec_sched)
        prof = ScheduleProfile(
            engine=label, num_steps=S, reps=max(1, reps), step_ms=step_ms,
            collective_ms=collective_ms, step_padded_flops=pf,
            step_real_flops=rf, step_bytes=sb, width_buckets=buckets)
        sp.set(total_ms=prof.total_ms(),
               critical_path_share=prof.critical_path_share(),
               utilization=prof.utilization())
        for s in prof.slowest_steps():
            sp.event("profile.step", step=s, ms=float(prof.step_ms[s]))
    return prof, x


def profile_schedule(sched, c, *, reps: int = 2, warmup: int = 1,
                     clock=time.perf_counter, mesh=None,
                     axis: str = "model") -> ScheduleProfile:
    """Profile one schedule execution per step (module doc).

    sched: a LevelSchedule or DeviceSchedule; c: the preamble-applied
    right-hand side, (n,) or (n, k).  Passing `mesh` profiles the sharded
    path and splits collective vs. compute per step.
    """
    from ..solver.levelset import DeviceSchedule
    host = sched.host if isinstance(sched, DeviceSchedule) else sched
    prof, _ = _profile_and_solve(host, c, reps=reps, warmup=warmup,
                                 clock=clock, mesh=mesh, axis=axis)
    return prof


def profile_operator(op, b=None, *, reps: int = 2, warmup: int = 1,
                     clock=time.perf_counter) -> ScheduleProfile:
    """Profile a built TriangularOperator's main schedule, with the
    operator's own orientation + preamble applied to `b` (default: ones),
    so the profiled c is exactly what a served solve would feed the
    schedule.  A sharded default engine routes its mesh/axis through."""
    from ..solver.engines import ShardedEngine
    v = np.ones(op.n, dtype=np.float64) if b is None else np.asarray(b)
    if op._reversed:
        v = v[::-1]
    c = op._ts.preamble(v)
    mesh, axis = None, "model"
    if isinstance(op._engine, ShardedEngine):
        mesh, axis = op._engine.resolve_mesh(), op._engine.axis
    return profile_schedule(op._sched, c, reps=reps, warmup=warmup,
                            clock=clock, mesh=mesh, axis=axis)


from ..solver.engines import Engine as _EngineBase  # noqa: E402  (needs
# the classes above at definition time; repro.obs.__init__ loads this
# module lazily, so the solver package never re-enters obs mid-import)


class ProfilingEngine(_EngineBase):
    """Engine-protocol wrapper running the per-step profiling loop.

    Opt-in measurement tool: per-step dispatch is deliberately paid so
    each step can be timed; do not register it as a serving default.
    `compile(sched)` returns a solve fn whose results are exact (the full
    per-step execution IS the solve); after each call `last_profile`
    holds the fresh ScheduleProfile.  Wrapping a ShardedEngine routes
    mesh/axis (and the collective split) through.
    """

    lowers_from_host = True

    def __init__(self, base=None, *, reps: int = 1, warmup: int = 1,
                 clock=time.perf_counter, name: str | None = None):
        self.base = base
        self.reps = int(reps)
        self.warmup = int(warmup)
        self.clock = clock
        self.name = name or f"profiled[{base.name if base else 'stepwise'}]"
        self.last_profile = None
        if base is not None:
            self.supports_batched_rhs = base.supports_batched_rhs
            self.dtypes = base.dtypes

    def available(self) -> bool:
        return self.base.available() if self.base is not None else True

    def cache_token(self) -> str:
        if self.base is not None:
            return f"{self.name}:{self.base.cache_token()}"
        return self.name

    def compile(self, sched):
        from ..solver.engines import ShardedEngine
        from ..solver.levelset import DeviceSchedule
        host = sched.host if isinstance(sched, DeviceSchedule) else sched
        self._require_dtype(host)
        mesh, axis = None, "model"
        if isinstance(self.base, ShardedEngine):
            mesh, axis = self.base.resolve_mesh(), self.base.axis

        def fn(cv):
            prof, x = _profile_and_solve(
                host, cv, reps=self.reps, warmup=self.warmup,
                clock=self.clock, mesh=mesh, axis=axis)
            self.last_profile = prof
            return x

        return fn

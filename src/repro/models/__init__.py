from . import api, attention, common, config, encdec, mamba2, mlp, rglru, \
    transformer
from .api import get_model
from .config import ArchConfig, MoEConfig, RecurrentConfig, SSMConfig

__all__ = ["api", "attention", "common", "config", "encdec", "mamba2", "mlp",
           "rglru", "transformer", "get_model", "ArchConfig", "MoEConfig",
           "RecurrentConfig", "SSMConfig"]

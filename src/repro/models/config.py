"""Architecture configuration schema.

One dataclass covers all 10 assigned families (dense / MoE / SSM / hybrid /
enc-dec / VLM+audio backbones).  Exact per-arch values live in
repro.configs.<arch>; every config there also provides a reduced smoke-test
variant via `reduced()`.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

__all__ = ["ArchConfig", "MoEConfig", "SSMConfig", "RecurrentConfig"]


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # dense fallback MLP interleaving (llama4 uses shared expert + moe)
    shared_expert: bool = False
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    chunk: int = 256              # SSD chunk length
    conv_width: int = 4


@dataclasses.dataclass(frozen=True)
class RecurrentConfig:
    lru_width: int = 0            # 0 => d_model
    window: int = 2048            # local attention window
    pattern: tuple[str, ...] = ("rec", "rec", "attn")  # 1:2 attn:rec


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec"]
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    vocab_pad_to: int = 256       # pad vocab so logits shard cleanly
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    act: Literal["silu", "gelu"] = "silu"   # SwiGLU vs GeGLU gate
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    moe: MoEConfig | None = None
    ssm: SSMConfig | None = None
    recurrent: RecurrentConfig | None = None
    # enc-dec only
    n_layers_decoder: int = 0
    # modality frontend stub: number of prefix embedding positions fed by
    # input_specs() (vlm: patch embeddings, audio: frame embeddings)
    frontend: Literal["none", "vlm", "audio"] = "none"
    frontend_positions: int = 0
    # attention flavour
    attention: Literal["full", "local", "none"] = "full"
    window: int = 0
    # numerics
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"
    # training-side defaults
    remat: bool = True
    remat_group: int = 4          # two-level remat: layers per saved group
    scan_layers: bool = True

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_to
        return -(-self.vocab // m) * m

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports 500k-token decode (SSM / hybrid)."""
        return self.family in ("ssm", "hybrid")

    def params_count(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            from .mamba2 import ssd_params_per_layer
            blk = ssd_params_per_layer(self)
            return emb + self.n_layers * blk
        att = d * (self.n_heads * hd) + d * (2 * self.n_kv * hd) \
            + (self.n_heads * hd) * d
        mlp = 3 * d * self.d_ff
        if self.family == "moe":
            mlp = self.moe.num_experts * 3 * d * self.moe.d_ff_expert
            if self.moe.shared_expert:
                mlp += 3 * d * self.d_ff
        per = att + mlp
        if self.family == "hybrid":
            # mix of RG-LRU blocks and attention blocks
            w = self.recurrent.lru_width or d
            rec = d * 2 * w + w * d + 3 * w  # in/out proj + gates (approx)
            att_layers = sum(1 for i in range(self.n_layers)
                             if self.recurrent.pattern[
                                 i % len(self.recurrent.pattern)] == "attn")
            rec_layers = self.n_layers - att_layers
            return emb + att_layers * (att + mlp) + rec_layers * (rec + mlp)
        total_layers = self.n_layers + self.n_layers_decoder
        if self.family == "encdec":
            # decoder layers add cross-attention
            return emb + self.n_layers * per + self.n_layers_decoder * (per + att)
        return emb + total_layers * per

    def active_params_count(self) -> int:
        """Active (per-token) params — differs from total for MoE."""
        if self.family != "moe":
            return self.params_count()
        d, v = self.d_model, self.padded_vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        att = d * (self.n_heads * hd) + d * (2 * self.n_kv * hd) \
            + (self.n_heads * hd) * d
        mlp = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        if self.moe.shared_expert:
            mlp += 3 * d * self.d_ff
        return emb + self.n_layers * (att + mlp)

"""RecurrentGemma-style hybrid: RG-LRU recurrent blocks + local attention
(arXiv:2402.19427), pattern (rec, rec, attn) — 1 attention per 2 recurrent.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)          # input gate
    a_t = exp(c * softplus(Lambda) * (-r_t))      # in (0,1), c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan (log-depth parallel linear
recurrence); decode is the O(1) step.  Layer stacking scans over homogeneous
pattern groups; remainder layers run unscanned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention, init_attention
from .common import (DTYPES, dense, embed, init_dense, init_embed,
                     init_rmsnorm, rmsnorm, silu, softmax_xent)
from .mlp import init_mlp, mlp

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_cache"]

LRU_C = 8.0


def _lru_width(cfg):
    return cfg.recurrent.lru_width or cfg.d_model


def _init_rec_block(key, cfg, dtype):
    d, w = cfg.d_model, _lru_width(cfg)
    k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
    return {
        "ln": init_rmsnorm(d, dtype),
        "in_x": init_dense(k1, d, w, dtype),
        "in_gate": init_dense(k2, d, w, dtype),
        "conv_w": (jax.random.normal(k3, (4, w), jnp.float32) * 0.1
                   ).astype(dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "gate_a": init_dense(k4, w, w, dtype),
        "gate_x": init_dense(k5, w, w, dtype),
        "lam": jnp.full((w,), 0.5, jnp.float32),   # Lambda (pre-softplus)
        "out": init_dense(k6, w, d, dtype),
        "ln2": init_rmsnorm(d, dtype),
    }


def _init_attn_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(ka, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
    }


def _init_mlp_part(key, cfg, dtype):
    return init_mlp(key, cfg.d_model, cfg.d_ff, dtype)


def _pattern(cfg):
    pat = cfg.recurrent.pattern
    return [pat[i % len(pat)] for i in range(cfg.n_layers)]


def _group_split(cfg):
    """n_layers = G full pattern repeats + a tail of pattern[:tail_n]."""
    plen = len(cfg.recurrent.pattern)
    G = cfg.n_layers // plen
    tail_n = cfg.n_layers - G * plen
    return G, tail_n


def _init_layer(key, kind, cfg, dtype):
    k1, k2 = jax.random.split(key)
    blk = (_init_rec_block(k1, cfg, dtype) if kind == "rec"
           else _init_attn_block(k1, cfg, dtype))
    blk["mlp"] = _init_mlp_part(k2, cfg, dtype)
    return blk


def init_params(key, cfg):
    """Pattern groups are stacked on a leading (G,) axis so the layer stack
    runs as ONE lax.scan over groups (python-unrolled layers defeat buffer
    reuse — 300+GB/chip at train_4k; EXPERIMENTS.md §Perf R1)."""
    dtype = DTYPES[cfg.param_dtype]
    ke, kb, kt, ko = jax.random.split(key, 4)
    pat = cfg.recurrent.pattern
    G, tail_n = _group_split(cfg)
    groups = []
    for p, kind in enumerate(pat):
        keys = jax.random.split(jax.random.fold_in(kb, p), G)
        groups.append(jax.vmap(
            lambda k: _init_layer(k, kind, cfg, dtype))(keys))
    tail = [
        _init_layer(jax.random.fold_in(kt, i), pat[i % len(pat)], cfg, dtype)
        for i in range(tail_n)]
    p = {"embed": init_embed(ke, cfg.padded_vocab, cfg.d_model, dtype),
         "groups": tuple(groups), "tail": tail,
         "ln_f": init_rmsnorm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = init_dense(ko, cfg.d_model, cfg.padded_vocab, dtype)
    return p


def layer_params(params, cfg, i: int):
    """Per-layer view (group slice or tail entry) for serve paths."""
    pat = cfg.recurrent.pattern
    plen = len(pat)
    G, tail_n = _group_split(cfg)
    if i < G * plen:
        g, p = divmod(i, plen)
        return jax.tree.map(lambda a: a[g], params["groups"][p])
    return params["tail"][i - G * plen]


def _conv_stream(x, w, b, state=None):
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    if state is None:
        return y
    return y, xp[:, -(W - 1):]


def _gates(bp, xb):
    """RG-LRU gate math for a (B, T, w) slice -> (a, b) recurrence coeffs."""
    f32 = jnp.float32
    r = jax.nn.sigmoid(dense(bp["gate_a"], xb).astype(f32))
    i = jax.nn.sigmoid(dense(bp["gate_x"], xb).astype(f32))
    log_a = -LRU_C * jax.nn.softplus(bp["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i * xb.astype(f32))
    return a, b


def _rglru(bp, xb, h0=None, chunk: int = 256):
    """xb (B,S,w) conv'd branch input; returns (y, h_last).

    Chunked linear recurrence: lax.scan over sequence chunks carrying the
    boundary state; gates AND the associative scan are computed per chunk
    under jax.checkpoint, so live f32 intermediates are O(B * chunk * w)
    instead of O(B * S * w) x ~6 tensors x log2(S) levels (the naive
    full-sequence version cost 300+GB/chip at train_4k; EXPERIMENTS §Perf).
    """
    f32 = jnp.float32
    if xb.shape[1] == 1 and h0 is not None:
        a, b = _gates(bp, xb)
        h = a[:, 0] * h0 + b[:, 0]
        return h[:, None].astype(xb.dtype), h
    B, S, w = xb.shape
    if h0 is None:
        h0 = jnp.zeros((B, w), f32)

    def combine(lhs, rhs):
        a1, b1 = lhs
        a2, b2 = rhs
        return a1 * a2, a2 * b1 + b2

    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        xb = jnp.pad(xb, ((0, 0), (0, pad), (0, 0)))
    xc = jnp.moveaxis(xb.reshape(B, nc, Q, w), 1, 0)

    @jax.checkpoint  # recompute gates + within-chunk scan in bwd, one chunk
    def chunk_fn(h, xj):
        aj, bj = _gates(bp, xj)
        bj = bj.at[:, 0].add(aj[:, 0] * h)
        _, hs = jax.lax.associative_scan(combine, (aj, bj), axis=1)
        return hs

    def body(h, xj):
        hs = chunk_fn(h, xj)
        return hs[:, -1], hs.astype(xb.dtype)

    h_last, hs = jax.lax.scan(body, h0, xc)
    h = jnp.moveaxis(hs, 0, 1).reshape(B, nc * Q, w)[:, :S]
    return h, h_last


def _rec_apply(bp, x, cfg, conv_state=None, lru_state=None):
    from ..train.meshctx import constrain_batch
    x = constrain_batch(x)
    res = x
    xi = rmsnorm(bp["ln"], x, cfg.norm_eps)
    xb = dense(bp["in_x"], xi)
    gate = dense(bp["in_gate"], xi)
    if conv_state is None:
        xb = _conv_stream(xb, bp["conv_w"], bp["conv_b"])
        new_conv = None
    else:
        xb, new_conv = _conv_stream(xb, bp["conv_w"], bp["conv_b"],
                                    state=conv_state)
    y, h_last = _rglru(bp, xb, lru_state)
    y = y * silu(gate)
    x = res + dense(bp["out"], y)
    hin = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    x = x + mlp(bp["mlp"], hin, cfg.act)
    if conv_state is None:
        return x
    return x, (new_conv, h_last)


def _attn_apply(bp, x, positions, cfg, kv_chunk=512):
    from ..train.meshctx import constrain_batch
    x = constrain_batch(x)
    h = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), positions,
                  cfg, kv_chunk=kv_chunk)
    x = x + h
    x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)
    return x


def forward(params, tokens, cfg, prefix_embeds=None, kv_chunk=512,
            return_hidden=False):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    pat = cfg.recurrent.pattern
    NP = jax.checkpoint_policies.nothing_saveable

    def apply_kind(kind, b, xx):
        if kind == "rec":
            return _rec_apply(b, xx, cfg)
        return _attn_apply(b, xx, positions, cfg, kv_chunk)

    def group_fn(gparams, xx):
        for p, kind in enumerate(pat):
            fn = functools.partial(apply_kind, kind)
            if cfg.remat:
                fn = jax.checkpoint(fn, policy=NP)
            xx = fn(gparams[p], xx)
        return xx

    def gbody(xx, gparams):
        fn = group_fn
        if cfg.remat:
            fn = jax.checkpoint(group_fn, policy=NP)
        return fn(gparams, xx), None

    x, _ = jax.lax.scan(gbody, x, params["groups"])
    G, tail_n = _group_split(cfg)
    for i in range(tail_n):
        kind = pat[i % len(pat)]
        fn = functools.partial(apply_kind, kind)
        if cfg.remat:
            fn = jax.checkpoint(fn, policy=NP)
        x = fn(params["tail"][i], x)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x, jnp.float32(0.0)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg, **_):
    from .common import lm_loss_chunked
    x, _ = forward(params, batch["tokens"], cfg,
                   prefix_embeds=batch.get("prefix_embeds"),
                   return_hidden=True)
    P = x.shape[1] - batch["labels"].shape[1]
    if P > 0:
        x = x[:, P:]
    w = (params["embed"]["w"] if cfg.tie_embeddings
         else params["unembed"]["w"])
    return lm_loss_chunked(x, w, batch["labels"], batch.get("mask"),
                           tied=cfg.tie_embeddings)


# -- serving -----------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int, dtype):
    """Mixed cache: per-layer entries (attention KV ring / LRU+conv state)."""
    w = _lru_width(cfg)
    hd = cfg.resolved_head_dim
    cache = []
    for kind in _pattern(cfg):
        if kind == "attn":
            win = min(cache_len, cfg.window or cache_len)
            cache.append({
                "k": jnp.zeros((batch, win, cfg.n_kv, hd), dtype),
                "v": jnp.zeros((batch, win, cfg.n_kv, hd), dtype)})
        else:
            cache.append({
                "conv": jnp.zeros((batch, 3, w), dtype),
                "h": jnp.zeros((batch, w), jnp.float32)})
    return cache


def prefill(params, tokens, cfg, cache_len: int, prefix_embeds=None,
            kv_chunk=512):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    hd = cfg.resolved_head_dim
    pat = _pattern(cfg)
    cache = []
    for i in range(cfg.n_layers):
        bp = layer_params(params, cfg, i)
        if pat[i] == "rec":
            conv0 = jnp.zeros((B, 3, _lru_width(cfg)), x.dtype)
            x, (conv_s, h_s) = _rec_apply(bp, x, cfg, conv_state=conv0)
            cache.append({"conv": conv_s, "h": h_s})
        else:
            h, (k, v) = attention(
                bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), positions,
                cfg, kv_chunk=kv_chunk, with_cache=True)
            x = x + h
            x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                        cfg.act)
            win = min(cache_len, cfg.window or cache_len)
            take = min(win, S)
            ks = jnp.zeros((B, win, cfg.n_kv, hd), k.dtype)
            vs = jnp.zeros((B, win, cfg.n_kv, hd), v.dtype)
            src_pos = S - take + jnp.arange(take)
            slots = jnp.mod(src_pos, win)
            ks = ks.at[:, slots].set(k[:, S - take:])
            vs = vs.at[:, slots].set(v[:, S - take:])
            cache.append({"k": ks, "v": vs})
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    last = x[:, -1:]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", last, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], last).astype(jnp.float32)
    return logits, cache


def decode_step(params, token, cache, pos, cfg):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], token).astype(adt)
    pat = _pattern(cfg)
    new_cache = []
    for i in range(cfg.n_layers):
        bp = layer_params(params, cfg, i)
        c = cache[i]
        if pat[i] == "rec":
            x, (conv_s, h_s) = _rec_apply(bp, x, cfg, conv_state=c["conv"],
                                          lru_state=c["h"])
            new_cache.append({"conv": conv_s, "h": h_s})
        else:
            h, ck, cv = decode_attention(
                bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps),
                c["k"], c["v"], pos, cfg)
            x = x + h
            x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps),
                        cfg.act)
            new_cache.append({"k": ck, "v": cv})
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, new_cache
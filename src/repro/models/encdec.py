"""Encoder-decoder transformer backbone (SeamlessM4T-v2 text/audio backbone).

The audio frontend is a STUB per the assignment: input_specs() supplies
precomputed frame embeddings (B, S_src, d) directly to the encoder.  The
decoder is a standard causal stack with cross-attention; decode uses a self
KV ring cache + static cross K/V computed once from the encoder output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention, init_attention
from .common import (DTYPES, dense, embed, init_dense, init_embed,
                     init_rmsnorm, rmsnorm, softmax_xent)
from .mlp import init_mlp, mlp

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step"]


def _init_enc_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(ka, cfg, dtype),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype)}


def _init_dec_block(key, cfg, dtype):
    ka, kc, km = jax.random.split(key, 3)
    return {"ln1": init_rmsnorm(cfg.d_model, dtype),
            "attn": init_attention(ka, cfg, dtype),
            "lnx": init_rmsnorm(cfg.d_model, dtype),
            "cross": init_attention(kc, cfg, dtype, cross=True),
            "ln2": init_rmsnorm(cfg.d_model, dtype),
            "mlp": init_mlp(km, cfg.d_model, cfg.d_ff, dtype)}


def init_params(key, cfg):
    dtype = DTYPES[cfg.param_dtype]
    ke, kenc, kdec, ko = jax.random.split(key, 4)
    enc_keys = jax.random.split(kenc, cfg.n_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers_decoder)
    if cfg.scan_layers:
        enc = jax.vmap(lambda k: _init_enc_block(k, cfg, dtype))(enc_keys)
        dec = jax.vmap(lambda k: _init_dec_block(k, cfg, dtype))(dec_keys)
    else:
        enc = [_init_enc_block(k, cfg, dtype) for k in enc_keys]
        dec = [_init_dec_block(k, cfg, dtype) for k in dec_keys]
    return {"embed": init_embed(ke, cfg.padded_vocab, cfg.d_model, dtype),
            "enc": enc, "dec": dec,
            "ln_enc": init_rmsnorm(cfg.d_model, dtype),
            "ln_f": init_rmsnorm(cfg.d_model, dtype),
            "unembed": init_dense(ko, cfg.d_model, cfg.padded_vocab, dtype)}


def _enc_apply(bp, x, positions, cfg, kv_chunk):
    from ..train.meshctx import constrain_batch
    x = constrain_batch(x)
    h = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), positions,
                  cfg, causal=False, kv_chunk=kv_chunk)
    x = x + h
    return x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)


def _dec_apply(bp, x, enc_out, positions, cfg, kv_chunk):
    from ..train.meshctx import constrain_batch
    x = constrain_batch(x)
    h = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), positions,
                  cfg, kv_chunk=kv_chunk)
    x = x + h
    hx = attention(bp["cross"], rmsnorm(bp["lnx"], x, cfg.norm_eps),
                   positions, cfg, kv_source=enc_out, causal=False,
                   kv_chunk=kv_chunk)
    x = x + hx
    return x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)


def encode(params, src_embeds, cfg, kv_chunk=512):
    adt = DTYPES[cfg.activation_dtype]
    x = src_embeds.astype(adt)
    positions = jnp.arange(x.shape[1])[None, :]

    if cfg.scan_layers:
        from .common import scan_blocks_grouped
        x = scan_blocks_grouped(
            lambda b, xx: _enc_apply(b, xx, positions, cfg, kv_chunk),
            x, params["enc"], remat=cfg.remat, group=cfg.remat_group,
            n_layers=cfg.n_layers)
    else:
        for bp in params["enc"]:
            x = _enc_apply(bp, x, positions, cfg, kv_chunk)
    return rmsnorm(params["ln_enc"], x, cfg.norm_eps)


def forward(params, batch_src, tgt_tokens, cfg, kv_chunk=512,
            return_hidden=False):
    """batch_src: (B, S_src, d) frame embeddings; tgt_tokens (B, S_tgt)."""
    adt = DTYPES[cfg.activation_dtype]
    enc_out = encode(params, batch_src, cfg, kv_chunk)
    x = embed(params["embed"], tgt_tokens).astype(adt)
    positions = jnp.arange(x.shape[1])[None, :]

    if cfg.scan_layers:
        from .common import scan_blocks_grouped
        x = scan_blocks_grouped(
            lambda b, xx: _dec_apply(b, xx, enc_out, positions, cfg,
                                     kv_chunk),
            x, params["dec"], remat=cfg.remat, group=cfg.remat_group,
            n_layers=cfg.n_layers_decoder)
    else:
        for bp in params["dec"]:
            x = _dec_apply(bp, x, enc_out, positions, cfg, kv_chunk)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x, jnp.float32(0.0)
    logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg, **_):
    from .common import lm_loss_chunked
    x, _ = forward(params, batch["src_embeds"], batch["tokens"], cfg,
                   return_hidden=True)
    return lm_loss_chunked(x, params["unembed"]["w"], batch["labels"],
                           batch.get("mask"), tied=False)


# -- serving -----------------------------------------------------------------

def prefill(params, tokens, cfg, cache_len: int, src_embeds=None,
            kv_chunk=512, **_):
    """Encode src, prefill decoder prompt; cache = self KV rings + cross KV."""
    assert src_embeds is not None
    adt = DTYPES[cfg.activation_dtype]
    enc_out = encode(params, src_embeds, cfg, kv_chunk)
    hd = cfg.resolved_head_dim
    x = embed(params["embed"], tokens).astype(adt)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]

    def one_block(bp, x):
        h, (k, v) = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps),
                              positions, cfg, kv_chunk=kv_chunk,
                              with_cache=True)
        x = x + h
        hx, (xk, xv) = attention(bp["cross"],
                                 rmsnorm(bp["lnx"], x, cfg.norm_eps),
                                 positions, cfg, kv_source=enc_out,
                                 causal=False, kv_chunk=kv_chunk,
                                 with_cache=True)
        x = x + hx
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)
        take = min(cache_len, S)
        ks = jnp.zeros((B, cache_len, cfg.n_kv, hd), k.dtype)
        vs = jnp.zeros((B, cache_len, cfg.n_kv, hd), v.dtype)
        src_pos = S - take + jnp.arange(take)
        slots = jnp.mod(src_pos, cache_len)
        ks = ks.at[:, slots].set(k[:, S - take:])
        vs = vs.at[:, slots].set(v[:, S - take:])
        return x, (ks, vs, xk, xv)

    if cfg.scan_layers:
        def body(x, bp):
            xn, c = one_block(bp, x)
            return xn, c
        x, (ck, cv, xk, xv) = jax.lax.scan(body, x, params["dec"])
    else:
        acc = []
        for bp in params["dec"]:
            x, c = one_block(bp, x)
            acc.append(c)
        ck, cv, xk, xv = (jnp.stack([a[i] for a in acc]) for i in range(4))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = dense(params["unembed"], x[:, -1:]).astype(jnp.float32)
    return logits, {"k": ck, "v": cv, "xk": xk, "xv": xv}


def decode_step(params, token, cache, pos, cfg):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], token).astype(adt)

    def one_block(x, bp_kv):
        bp, ck, cv, xk, xv = bp_kv
        h, ck, cv = decode_attention(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), ck, cv, pos, cfg)
        x = x + h
        hx, _, _ = decode_attention(
            bp["cross"], rmsnorm(bp["lnx"], x, cfg.norm_eps), xk, xv, pos,
            cfg, cross=True)
        x = x + hx
        x = x + mlp(bp["mlp"], rmsnorm(bp["ln2"], x, cfg.norm_eps), cfg.act)
        return x, (ck, cv)

    if cfg.scan_layers:
        def body(x, bp_kv):
            xn, kv = one_block(x, bp_kv)
            return xn, kv
        x, (ck, cv) = jax.lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
    else:
        cks, cvs = [], []
        for i, bp in enumerate(params["dec"]):
            x, (k1, v1) = one_block(x, (bp, cache["k"][i], cache["v"][i],
                                        cache["xk"][i], cache["xv"][i]))
            cks.append(k1); cvs.append(v1)
        ck, cv = jnp.stack(cks), jnp.stack(cvs)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}
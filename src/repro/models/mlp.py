"""Gated MLPs (SwiGLU / GeGLU) and Mixture-of-Experts layers.

MoE routing is dense-dispatch (one-hot combine einsums): every token's
hidden state is dispatched to its top-k experts under a capacity limit.
Expert weights are stacked on a leading E axis and sharded over the 'model'
mesh axis; the dispatch einsums lower to all-to-all style resharding in SPMD.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense, init_dense, gelu, silu

__all__ = ["init_mlp", "mlp", "init_moe", "moe"]


def init_mlp(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": init_dense(k1, d_model, d_ff, dtype),
        "up": init_dense(k2, d_model, d_ff, dtype),
        "down": init_dense(k3, d_ff, d_model, dtype),
    }


def mlp(p, x, act: str = "silu"):
    g = dense(p["gate"], x)
    g = silu(g) if act == "silu" else gelu(g)
    return dense(p["down"], g * dense(p["up"], x))


def init_moe(key, cfg, dtype):
    mo = cfg.moe
    d, dff, E = cfg.d_model, mo.d_ff_expert, mo.num_experts
    kr, kg, ku, kd, ks = jax.random.split(key, 5)
    import numpy as np
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": init_dense(kr, d, E, dtype),
        "gate_w": (jax.random.normal(kg, (E, d, dff), jnp.float32)
                   * scale).astype(dtype),
        "up_w": (jax.random.normal(ku, (E, d, dff), jnp.float32)
                 * scale).astype(dtype),
        "down_w": (jax.random.normal(kd, (E, dff, d), jnp.float32)
                   / np.sqrt(dff)).astype(dtype),
    }
    if mo.shared_expert:
        p["shared"] = init_mlp(ks, d, cfg.d_ff, dtype)
    return p


def moe(p, x, cfg, act: str = "silu"):
    """x: (B, S, d) -> (B, S, d); returns (y, aux_loss).

    Group-local scatter/gather dispatch (GSPMD MoE pattern):
      * tokens are grouped per batch row; routing, capacity queues and the
        dispatch gather are GROUP-LOCAL (no global collectives);
      * dispatch stage shards groups over (data x model) — every chip routes
        its own groups;
      * the (groups:'data', experts:'model') constraint before the expert
        matmuls lowers to the canonical MoE all-to-all (~E*cap*d per chip),
        and back after — measured 38x collective-bytes reduction vs a global
        dispatch (EXPERIMENTS.md §Perf).
    Capacity: cap = ceil(capacity_factor * S * k / E) per (group, expert);
    dropped tokens pass through with zero expert contribution.
    """
    from ..train.meshctx import constrain_tokens, constrain_group_expert
    mo = cfg.moe
    B, S, d = x.shape
    E, k = mo.num_experts, mo.top_k
    G, Tg = B, S                                             # group = row
    xt = constrain_tokens(x)                                 # (G, Tg, d)
    logits = dense(p["router"], xt).astype(jnp.float32)      # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, sel = jax.lax.top_k(probs, k)                 # (G, Tg, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    cap = max(1, -(-int(mo.capacity_factor * Tg * k) // E))
    # per-(group, expert) queue positions
    sel_flat = sel.reshape(G, Tg * k)
    one_hot_e = jax.nn.one_hot(sel_flat, E, dtype=jnp.int32)  # (G, Tg*k, E)
    pos_in_e = jnp.cumsum(one_hot_e, axis=1) - one_hot_e
    pos = jnp.take_along_axis(
        pos_in_e, sel_flat[..., None], axis=2)[..., 0]       # (G, Tg*k)
    keep = pos < cap
    gate_vals = gate_vals * keep.reshape(G, Tg, k)

    # group-local scatter of token ids into expert queues
    slot = jnp.where(keep, sel_flat * cap + pos, E * cap)    # (G, Tg*k)
    token_id = jnp.tile(jnp.arange(Tg)[:, None], (1, k)).reshape(1, Tg * k)
    token_id = jnp.broadcast_to(token_id, (G, Tg * k))
    slot_token = jnp.full((G, E * cap + 1), Tg, dtype=jnp.int32)
    slot_token = jax.vmap(lambda st, sl, ti: st.at[sl].set(ti))(
        slot_token, slot, token_id)
    xt_pad = jnp.concatenate([xt, jnp.zeros((G, 1, d), xt.dtype)], axis=1)
    xe = jax.vmap(lambda xg, st: xg[st[:-1]])(xt_pad, slot_token)
    xe = xe.reshape(G, E, cap, d)
    xe = constrain_tokens(xe)                # dispatch: groups everywhere
    xe = constrain_group_expert(xe)          # -> all-to-all to expert shards

    g = jnp.einsum("gecd,edf->gecf", xe, p["gate_w"],
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    u = jnp.einsum("gecd,edf->gecf", xe, p["up_w"],
                   preferred_element_type=jnp.float32).astype(xt.dtype)
    h = (silu(g) if act == "silu" else gelu(g)) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["down_w"],
                    preferred_element_type=jnp.float32).astype(xt.dtype)
    ye = constrain_group_expert(ye)
    ye = constrain_tokens(ye)                # all-to-all back to token shards

    # group-local combine
    ye_flat = ye.reshape(G, E * cap, d)
    ye_flat = jnp.concatenate([ye_flat, jnp.zeros((G, 1, d), ye.dtype)],
                              axis=1)
    yk = jax.vmap(lambda yg, sl: yg[sl])(ye_flat, slot)      # (G, Tg*k, d)
    yk = yk.reshape(G, Tg, k, d)
    yt = jnp.einsum("gtkd,gtk->gtd", yk, gate_vals.astype(jnp.float32)
                    ).astype(xt.dtype)
    y = yt.reshape(B, S, d)
    if mo.shared_expert:
        y = y + mlp(p["shared"], x, act)

    # load-balance auxiliary loss (Switch style)
    me = jnp.mean(probs, axis=(0, 1))                        # (E,)
    ce = jnp.mean(jax.nn.one_hot(sel[..., 0], E, dtype=jnp.float32),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    return y, aux

"""Decoder-only transformer assembly (dense + MoE families, VLM prefix).

Params layout (scan_layers=True): every block parameter is stacked on a
leading (n_layers,) axis and the stack is executed with jax.lax.scan — HLO
size and compile time are O(1) in depth (MaxText-style), remat-able per
layer.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .attention import attention, decode_attention, init_attention
from .common import (DTYPES, dense, embed, init_dense, init_embed,
                     init_rmsnorm, rmsnorm, softmax_xent)
from .mlp import init_mlp, init_moe, mlp, moe

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_cache"]


def _init_block(key, cfg, dtype):
    ka, km = jax.random.split(key)
    p = {
        "ln1": init_rmsnorm(cfg.d_model, dtype),
        "attn": init_attention(ka, cfg, dtype),
        "ln2": init_rmsnorm(cfg.d_model, dtype),
    }
    if cfg.family == "moe":
        p["moe"] = init_moe(km, cfg, dtype)
    else:
        p["mlp"] = init_mlp(km, cfg.d_model, cfg.d_ff, dtype)
    return p


def init_params(key, cfg):
    dtype = DTYPES[cfg.param_dtype]
    ke, kb, ko = jax.random.split(key, 3)
    if cfg.scan_layers:
        keys = jax.random.split(kb, cfg.n_layers)
        blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
    else:
        blocks = [_init_block(k, cfg, dtype)
                  for k in jax.random.split(kb, cfg.n_layers)]
    p = {
        "embed": init_embed(ke, cfg.padded_vocab, cfg.d_model, dtype),
        "blocks": blocks,
        "ln_f": init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        p["unembed"] = init_dense(ko, cfg.d_model, cfg.padded_vocab, dtype)
    return p


def _block_apply(bp, x, positions, cfg, kv_chunk=512):
    from ..train.meshctx import constrain_batch
    x = constrain_batch(x)
    h = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), positions,
                  cfg, kv_chunk=kv_chunk)
    x = x + h
    hin = rmsnorm(bp["ln2"], x, cfg.norm_eps)
    if cfg.family == "moe":
        m, aux = moe(bp["moe"], hin, cfg, cfg.act)
    else:
        m, aux = mlp(bp["mlp"], hin, cfg.act), jnp.float32(0.0)
    return x + m, aux


def forward(params, tokens, cfg, prefix_embeds=None, kv_chunk=512,
            return_hidden=False):
    """tokens (B, S) int32 -> logits (B, S_total, V).

    prefix_embeds (B, P, d): modality-frontend stub output (vlm/audio),
    prepended before the token embeddings.  return_hidden skips the unembed
    (the chunked LM loss applies it per sequence chunk instead).
    """
    from ..train.meshctx import constrain_batch
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    x = constrain_batch(x)
    S = x.shape[1]
    positions = jnp.arange(S)[None, :]
    aux_total = jnp.float32(0.0)
    if cfg.scan_layers:
        from .common import scan_blocks_grouped

        def block_fn(bp, carry):
            x, aux = carry
            xn, a = _block_apply(bp, x, positions, cfg, kv_chunk)
            return (xn, aux + a)

        x, aux_total = scan_blocks_grouped(
            block_fn, (x, aux_total), params["blocks"], remat=cfg.remat,
            group=cfg.remat_group, n_layers=cfg.n_layers)
    else:
        for bp in params["blocks"]:
            x, a = _block_apply(bp, x, positions, cfg, kv_chunk)
            aux_total = aux_total + a
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x, aux_total
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, aux_total


def loss_fn(params, batch, cfg, kv_chunk=512):
    """batch: {tokens (B,S), labels (B,S), mask (B,S)} (+ prefix_embeds)."""
    from .common import lm_loss_chunked
    x, aux = forward(params, batch["tokens"], cfg,
                     prefix_embeds=batch.get("prefix_embeds"),
                     kv_chunk=kv_chunk, return_hidden=True)
    P = x.shape[1] - batch["labels"].shape[1]
    if P > 0:  # frontend prefix positions carry no next-token loss
        x = x[:, P:]
    w = (params["embed"]["w"] if cfg.tie_embeddings
         else params["unembed"]["w"])
    ce = lm_loss_chunked(x, w, batch["labels"], batch.get("mask"),
                         tied=cfg.tie_embeddings)
    return ce + 0.01 * aux


# -- serving ------------------------------------------------------------------

def init_cache(cfg, batch: int, cache_len: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (cfg.n_layers, batch, cache_len, cfg.n_kv, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def prefill(params, tokens, cfg, cache_len: int, prefix_embeds=None,
            kv_chunk=512):
    """Run the prompt, return (last_logits, cache).

    The cache stores each layer's K/V in ring layout (slot = pos % cache_len)
    so decode_step can continue seamlessly for both full and local attention.
    """
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    B, S = x.shape[:2]
    positions = jnp.arange(S)[None, :]
    hd = cfg.resolved_head_dim

    def one_block(bp, x):
        h, (k, v) = attention(bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps),
                              positions, cfg, kv_chunk=kv_chunk,
                              with_cache=True)
        x = x + h
        hin = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = moe(bp["moe"], hin, cfg, cfg.act)
        else:
            m = mlp(bp["mlp"], hin, cfg.act)
        # ring layout: position p -> slot p % cache_len (take the last
        # cache_len positions; older ones are out of any window anyway)
        take = min(cache_len, S)
        ks = jnp.zeros((B, cache_len, cfg.n_kv, hd), k.dtype)
        vs = jnp.zeros((B, cache_len, cfg.n_kv, hd), v.dtype)
        src_pos = S - take + jnp.arange(take)
        slots = jnp.mod(src_pos, cache_len)
        ks = ks.at[:, slots].set(k[:, S - take:])
        vs = vs.at[:, slots].set(v[:, S - take:])
        return x + m, (ks, vs)

    if cfg.scan_layers:
        def body(x, bp):
            xn, kv = one_block(bp, x)
            return xn, kv
        x, (ck, cv) = jax.lax.scan(body, x, params["blocks"])
    else:
        cks, cvs = [], []
        for bp in params["blocks"]:
            x, (k1, v1) = one_block(bp, x)
            cks.append(k1); cvs.append(v1)
        ck, cv = jnp.stack(cks), jnp.stack(cvs)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    last = x[:, -1:]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", last, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], last).astype(jnp.float32)
    return logits, {"k": ck, "v": cv}


def decode_step(params, token, cache, pos, cfg):
    """One decode step.  token (B, 1) int32; pos: absolute position (traced
    scalar); returns (logits (B,1,V), new cache)."""
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], token).astype(adt)

    def one_block(x, bp_kv):
        bp, (ck, cv) = bp_kv
        h, ck, cv = decode_attention(
            bp["attn"], rmsnorm(bp["ln1"], x, cfg.norm_eps), ck, cv, pos, cfg)
        x = x + h
        hin = rmsnorm(bp["ln2"], x, cfg.norm_eps)
        if cfg.family == "moe":
            m, _ = moe(bp["moe"], hin, cfg, cfg.act)
        else:
            m = mlp(bp["mlp"], hin, cfg.act)
        return x + m, (ck, cv)

    if cfg.scan_layers:
        def body(x, bp_kv):
            xn, kv = one_block(x, bp_kv)
            return xn, kv
        x, (ck, cv) = jax.lax.scan(body, x,
                                   (params["blocks"],
                                    (cache["k"], cache["v"])))
    else:
        cks, cvs = [], []
        for i, bp in enumerate(params["blocks"]):
            x, (k1, v1) = one_block(x, (bp, (cache["k"][i], cache["v"][i])))
            cks.append(k1); cvs.append(v1)
        ck, cv = jnp.stack(cks), jnp.stack(cvs)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, {"k": ck, "v": cv}
"""Unified model API: family dispatch for init/loss/serve entry points."""
from __future__ import annotations

import types

from . import encdec, mamba2, rglru, transformer
from .config import ArchConfig

__all__ = ["get_model"]


def get_model(cfg: ArchConfig) -> types.ModuleType:
    """Return the module implementing cfg's family.

    Every module exposes: init_params(key, cfg); loss_fn(params, batch, cfg);
    prefill(params, tokens, cfg, cache_len, ...); decode_step(params, token,
    cache, pos, cfg).  (encdec's loss takes batch with src_embeds.)
    """
    if cfg.family in ("dense", "moe"):
        return transformer
    if cfg.family == "ssm":
        return mamba2
    if cfg.family == "hybrid":
        return rglru
    if cfg.family == "encdec":
        return encdec
    raise KeyError(cfg.family)

"""GQA attention: chunked (flash-style) training/prefill path + cached decode.

The chunked path scans over KV blocks with an online-softmax accumulator so
activation memory is O(S * kv_chunk) instead of O(S^2) — required to lower
prefill_32k (32768 tokens x batch 32) at all, and the right structure for TPU
(each (q_chunk, kv_chunk) tile is an MXU-shaped matmul).

Supports:
  * grouped-query attention (n_kv < n_heads), MQA (n_kv = 1);
  * optional QKV bias (qwen2), head_dim != d_model/n_heads (gemma);
  * causal masking, local (sliding-window) masking (recurrentgemma);
  * cross-attention (no causal mask, separate KV source, enc-dec);
  * decode step against a (possibly ring-buffered local) KV cache.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from .common import dense, init_dense, rope

__all__ = ["init_attention", "attention", "decode_attention", "AttnParams"]

NEG_INF = -1e30


def init_attention(key, cfg, dtype, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q": init_dense(kq, d, cfg.n_heads * hd, dtype, bias=cfg.qkv_bias),
        "k": init_dense(kk, d, cfg.n_kv * hd, dtype, bias=cfg.qkv_bias),
        "v": init_dense(kv, d, cfg.n_kv * hd, dtype, bias=cfg.qkv_bias),
        "o": init_dense(ko, cfg.n_heads * hd, d, dtype, bias=False),
    }


AttnParams = dict


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _chunked_attn(q, k, v, *, causal: bool, window: int, q_offset: int,
                  kv_chunk: int = 512, q_chunk: int = 512):
    """Flash-style online-softmax attention, chunked over BOTH q and kv.

    q: (B, Sq, H, hd); k/v: (B, Sk, K, hd) with H % K == 0.  K/V are expanded
    to the full H heads (head h <- kv head h // G) so the head dimension
    shards over the 'model' mesh axis in one piece — GQA's split (K, G) dims
    rarely divide a 16-way axis and GSPMD otherwise re-gathers the flash
    accumulators on EVERY kv step (measured: 62k all-gathers/step before
    this change; see EXPERIMENTS.md §Perf).  Peak activation memory is
    O(q_chunk * kv_chunk) per (batch, head).  Returns (B, Sq, H, hd).
    """
    B, Sq, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / np.sqrt(hd)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)     # (B, Sk, H, hd); order k*G+g
        v = jnp.repeat(v, G, axis=2)
    # pad heads to a multiple of the model axis so the head dim shards in
    # one piece (odd head counts — 40/28/14 — otherwise force replicated
    # flash carries and a re-gather on every kv step)
    from ..train.meshctx import constrain_batch, model_axis_size
    msz = model_axis_size()
    H_orig = H
    if H % msz:
        hp = (-(-H // msz)) * msz - H
        q = jnp.pad(q, ((0, 0), (0, 0), (0, hp), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, hp), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, hp), (0, 0)))
        H += hp

    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Sk)
    nq = -(-Sq // q_chunk)
    nk = -(-Sk // kv_chunk)
    qp, kp = nq * q_chunk - Sq, nk * kv_chunk - Sk
    if qp:
        q = jnp.pad(q, ((0, 0), (0, qp), (0, 0), (0, 0)))
    if kp:
        k = jnp.pad(k, ((0, 0), (0, kp), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, kp), (0, 0), (0, 0)))
    qc = jnp.moveaxis(q.reshape(B, nq, q_chunk, H, hd), 1, 0)
    kc = jnp.moveaxis(k.reshape(B, nk, kv_chunk, H, hd), 1, 0)
    vc = jnp.moveaxis(v.reshape(B, nk, kv_chunk, H, hd), 1, 0)

    cb = lambda x: constrain_batch(x, 0, model_dim=2)  # (B, qc, H, ...)

    @jax.checkpoint  # recompute per q-block in bwd: only one block's kv-scan
    def q_block_inner(qi, i):  # residuals are live at a time
        qi = qi.astype(jnp.float32) * scale
        q_pos = q_offset + i * q_chunk + jnp.arange(q_chunk)

        @jax.checkpoint  # s/p rematerialized per kv step in bwd
        def kv_block_inner(m, l, acc, kj, vj, j):
            kv_pos = j * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum("bqhe,bche->bqhc", qi, kj.astype(jnp.float32))
            valid = (kv_pos < Sk)[None, None, None, :]
            if causal:
                cm = kv_pos[None, :] <= q_pos[:, None]       # (qc, c)
                if window:
                    cm &= kv_pos[None, :] > q_pos[:, None] - window
                valid = valid & cm[None, :, None, :]
            s = jnp.where(valid, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bqhc,bche->bqhe", p, vj.astype(jnp.float32))
            return m_new, l, acc

        def kv_block(carry, ys):
            m, l, acc = carry
            kj, vj, j = ys
            m, l, acc = kv_block_inner(m, l, acc, kj, vj, j)
            return (cb(m), cb(l), cb(acc)), None

        m0 = cb(jnp.full((B, q_chunk, H), NEG_INF, dtype=jnp.float32))
        l0 = cb(jnp.zeros((B, q_chunk, H), dtype=jnp.float32))
        acc0 = cb(jnp.zeros((B, q_chunk, H, hd), dtype=jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            kv_block, (m0, l0, acc0), (kc, vc, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-30)
        return out.astype(q.dtype)           # (B, qc, H, hd)

    def q_block(_, xs):
        qi, i = xs
        return None, cb(q_block_inner(qi, i))

    _, blocks = jax.lax.scan(q_block, None, (qc, jnp.arange(nq)))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, nq * q_chunk, H, hd)
    return out[:, :Sq, :H_orig]


def attention(p, x, positions, cfg, *, kv_source=None, causal=True,
              kv_chunk: int = 512, q_offset: int = 0, with_cache=False):
    """Full attention over x (training / prefill).

    kv_source: encoder output for cross-attention (then causal=False).
    Returns y or (y, (k, v)) when with_cache.
    """
    hd = cfg.resolved_head_dim
    src = x if kv_source is None else kv_source
    q = _split_heads(dense(p["q"], x), cfg.n_heads, hd)
    k = _split_heads(dense(p["k"], src), cfg.n_kv, hd)
    v = _split_heads(dense(p["v"], src), cfg.n_kv, hd)
    if kv_source is None:  # self-attention gets RoPE
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    window = cfg.window if cfg.attention == "local" else 0
    y = _chunked_attn(q, k, v, causal=causal, window=window,
                      q_offset=q_offset, kv_chunk=kv_chunk)
    y = dense(p["o"], y.reshape(y.shape[:2] + (cfg.n_heads * hd,)))
    if with_cache:
        return y, (k, v)
    return y


def decode_attention(p, x, cache_k, cache_v, pos, cfg, *, cross=False):
    """Single-token decode against a KV cache.

    x: (B, 1, d); cache_k/v: (B, S_cache, K, hd); pos: scalar int (absolute
    position of the new token).  For self-attention the new token's K/V are
    written at index `pos % S_cache` (ring buffer semantics cover both full
    and local-window caches).  Returns (y, cache_k, cache_v).
    """
    hd = cfg.resolved_head_dim
    q = _split_heads(dense(p["q"], x), cfg.n_heads, hd)
    if not cross:
        k_new = _split_heads(dense(p["k"], x), cfg.n_kv, hd)
        v_new = _split_heads(dense(p["v"], x), cfg.n_kv, hd)
        positions = jnp.full((x.shape[0], 1), pos, dtype=jnp.int32)
        q = rope(q, positions, cfg.rope_theta)
        k_new = rope(k_new, positions, cfg.rope_theta)
        S_cache = cache_k.shape[1]
        slot = jnp.mod(pos, S_cache)
        cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k_new, slot, 1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v_new, slot, 1)
    B, S_cache, K, _ = cache_k.shape
    G = cfg.n_heads // K
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, 1, K, G, hd).astype(jnp.float32) * scale
    s = jnp.einsum("bqkgh,bckh->bqgkc", qg, cache_k.astype(jnp.float32))
    cache_pos = jnp.arange(S_cache)
    if cross:
        valid = jnp.ones((S_cache,), dtype=bool)
    else:
        valid = _ring_valid(cache_pos, pos, S_cache,
                            cfg.window if cfg.attention == "local" else 0)
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    y = jnp.einsum("bqgkc,bckh->bqgkh", w, cache_v.astype(jnp.float32))
    y = jnp.moveaxis(y, 3, 2).reshape(B, 1, cfg.n_heads * hd)  # (K,G) order
    y = y.astype(x.dtype)
    return dense(p["o"], y), cache_k, cache_v


def _ring_valid(slots, pos, S_cache, window):
    """Which ring slots hold valid (written, in-window) positions."""
    stored = pos - jnp.mod(pos - slots, S_cache)   # absolute positions
    ok = stored >= 0
    if window:
        ok &= stored > pos - window
    return ok

"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked SSD algorithm: the sequence is split into chunks of length Q;
within-chunk outputs use the quadratic (attention-like) form with a decay
mask, cross-chunk contributions flow through the recurrent chunk states
(one lax.scan over chunks).  Decode is the O(1) recurrent update.

Shapes: d_inner = expand*d_model, H = d_inner/P heads, state N per head.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import DTYPES, dense, init_dense, init_embed, init_rmsnorm, \
    embed, rmsnorm, silu, softmax_xent

__all__ = ["init_params", "forward", "loss_fn", "prefill", "decode_step",
           "init_state", "ssd_params_per_layer"]


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    H = d_inner // s.head_dim
    return d_inner, H, s.head_dim, s.state_dim


def ssd_params_per_layer(cfg) -> int:
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    return (d * (2 * d_inner + 2 * N + H)      # in_proj (z, x, B, C, dt)
            + conv_dim * cfg.ssm.conv_width    # depthwise conv
            + 2 * H                            # A_log, D
            + H                                # dt bias
            + d_inner * d)                     # out_proj


def _init_block(key, cfg, dtype):
    d = cfg.d_model
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln": init_rmsnorm(d, dtype),
        "in_proj": init_dense(k1, d, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(k2, (cfg.ssm.conv_width, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log)=-1
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "out_proj": init_dense(k3, d_inner, d, dtype),
        "ln_out": init_rmsnorm(d_inner, dtype),
    }


def init_params(key, cfg):
    dtype = DTYPES[cfg.param_dtype]
    ke, kb, ko = jax.random.split(key, 3)
    keys = jax.random.split(kb, cfg.n_layers)
    blocks = jax.vmap(lambda k: _init_block(k, cfg, dtype))(keys)
    p = {"embed": init_embed(ke, cfg.padded_vocab, cfg.d_model, dtype),
         "blocks": blocks, "ln_f": init_rmsnorm(cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        p["unembed"] = init_dense(ko, cfg.d_model, cfg.padded_vocab, dtype)
    return p


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv.  x (B,S,C), w (W,C).  If state (B,W-1,C) is
    given, runs in streaming mode and returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    y = y + b
    if state is None:
        return y
    return y, xp[:, -(W - 1):]


def _ssd_chunked(xh, dt, A, Bm, Cm, chunk, h0=None):
    """Chunked SSD.

    xh (B,S,H,P); dt (B,S,H) (softplus'ed); A (H,) negative; Bm/Cm (B,S,N).
    Returns (y (B,S,H,P), h_final (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    xc = xh.reshape(Bsz, nc, Q, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, Q, H).astype(f32)
    Bc = Bm.reshape(Bsz, nc, Q, N).astype(f32)
    Cc = Cm.reshape(Bsz, nc, Q, N).astype(f32)

    dA = dtc * A[None, None, None, :]                 # (B,nc,Q,H) negative
    cum = jnp.cumsum(dA, axis=2)                      # within-chunk cumsum
    # decay from position j to end of chunk / from start to position i
    seg_end = cum[:, :, -1:, :]                       # total chunk decay
    # intra-chunk mask: L[i,j] = exp(cum_i - cum_j) for j <= i
    Li = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,Q,Q,H)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(Li), 0.0)
    xdt = xc * dtc[..., None]                         # (B,nc,Q,H,P)
    # diagonal (within-chunk) term
    G = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)         # (B,nc,Q,Q)
    y_diag = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", G, L, xdt)
    # chunk states: contribution of chunk c to the carried state
    decay_out = jnp.exp(seg_end - cum)                # (B,nc,Q,H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", Bc, decay_out, xdt)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(seg_end[:, :, 0, :])        # (B,nc,H)

    def scan_fn(h, inp):
        st, dec = inp
        h_out = h
        h = h * dec[..., None, None] + st
        return h, h_out

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), f32)
    hT, h_prev = jax.lax.scan(
        scan_fn, h0,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)               # (B,nc,H,P,N)
    decay_in = jnp.exp(cum)                           # (B,nc,Q,H)
    y_off = jnp.einsum("bcqn,bcqh,bchpn->bcqhp", Cc, decay_in, h_prev)
    y = (y_diag + y_off).reshape(Bsz, nc * Q, H, P)
    return y[:, :S].astype(xh.dtype), hT


def _block_apply(bp, x, cfg, conv_state=None, ssd_state=None):
    """One Mamba-2 block.  Streaming when states are provided."""
    from ..train.meshctx import constrain_batch
    d_inner, H, P, N = _dims(cfg)
    s = cfg.ssm
    x = constrain_batch(x)
    residual = x
    x = rmsnorm(bp["ln"], x, cfg.norm_eps)
    zxbcdt = dense(bp["in_proj"], x)
    z, xr, Bm, Cm, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    xbc = jnp.concatenate([xr, Bm, Cm], axis=-1)
    if conv_state is None:
        xbc = _causal_conv(xbc, bp["conv_w"], bp["conv_b"])
        new_conv = None
    else:
        xbc, new_conv = _causal_conv(xbc, bp["conv_w"], bp["conv_b"],
                                     state=conv_state)
    xbc = silu(xbc)
    xr, Bm, Cm = jnp.split(xbc, [d_inner, d_inner + N], axis=-1)
    Bsz, S = xr.shape[:2]
    xh = xr.reshape(Bsz, S, H, P)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + bp["dt_bias"][None, None, :])
    A = -jnp.exp(bp["A_log"])
    if ssd_state is None and S > 1:
        y, hT = _ssd_chunked(xh, dt, A, Bm, Cm, s.chunk)
    else:
        h0 = ssd_state if ssd_state is not None else \
            jnp.zeros((Bsz, H, P, N), jnp.float32)
        # single-step recurrence (decode)
        dA = jnp.exp(dt[:, 0] * A[None, :])                  # (B,H)
        xdt = (xh[:, 0].astype(jnp.float32)
               * dt[:, 0][..., None])                        # (B,H,P)
        hT = h0 * dA[..., None, None] + jnp.einsum(
            "bn,bhp->bhpn", Bm[:, 0].astype(jnp.float32), xdt)
        y = jnp.einsum("bn,bhpn->bhp", Cm[:, 0].astype(jnp.float32),
                       hT)[:, None].astype(xh.dtype)
    y = y + xh * bp["D"][None, None, :, None].astype(xh.dtype)
    y = y.reshape(Bsz, S, d_inner)
    y = rmsnorm(bp["ln_out"], y * silu(z), cfg.norm_eps)
    out = residual + dense(bp["out_proj"], y)
    if conv_state is None:
        return out
    return out, (new_conv, hT)


def forward(params, tokens, cfg, prefix_embeds=None, return_hidden=False,
            **_):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)

    from .common import scan_blocks_grouped
    x = scan_blocks_grouped(
        lambda bp, xx: _block_apply(bp, xx, cfg), x, params["blocks"],
        remat=cfg.remat, group=cfg.remat_group, n_layers=cfg.n_layers)
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if return_hidden:
        return x, jnp.float32(0.0)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, jnp.float32(0.0)


def loss_fn(params, batch, cfg, **_):
    from .common import lm_loss_chunked
    x, _ = forward(params, batch["tokens"], cfg,
                   prefix_embeds=batch.get("prefix_embeds"),
                   return_hidden=True)
    P = x.shape[1] - batch["labels"].shape[1]
    if P > 0:
        x = x[:, P:]
    w = (params["embed"]["w"] if cfg.tie_embeddings
         else params["unembed"]["w"])
    return lm_loss_chunked(x, w, batch["labels"], batch.get("mask"),
                           tied=cfg.tie_embeddings)


# -- serving -----------------------------------------------------------------

def init_state(cfg, batch: int, dtype):
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N
    W = cfg.ssm.conv_width
    return {
        "conv": jnp.zeros((cfg.n_layers, batch, W - 1, conv_dim), dtype),
        "ssd": jnp.zeros((cfg.n_layers, batch, H, P, N), jnp.float32),
    }


def prefill(params, tokens, cfg, cache_len: int = 0, prefix_embeds=None,
            **_):
    """Returns (last_logits, state).  cache_len unused (state is O(1))."""
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    W = cfg.ssm.conv_width
    Bsz = x.shape[0]
    d_inner, H, P, N = _dims(cfg)
    conv_dim = d_inner + 2 * N

    def body(x, bp):
        conv0 = jnp.zeros((Bsz, W - 1, conv_dim), x.dtype)
        out, (conv_s, ssd_s) = _block_apply(bp, x, cfg, conv_state=conv0)
        return out, (conv_s, ssd_s)

    x, (conv_s, ssd_s) = jax.lax.scan(body, x, params["blocks"])
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    last = x[:, -1:]
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", last, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], last).astype(jnp.float32)
    return logits, {"conv": conv_s, "ssd": ssd_s}


def decode_step(params, token, state, pos, cfg):
    adt = DTYPES[cfg.activation_dtype]
    x = embed(params["embed"], token).astype(adt)

    def body(x, bp_state):
        bp, conv_s, ssd_s = bp_state
        out, (conv_n, ssd_n) = _block_apply(bp, x, cfg, conv_state=conv_s,
                                            ssd_state=ssd_s)
        return out, (conv_n, ssd_n)

    x, (conv_s, ssd_s) = jax.lax.scan(
        body, x, (params["blocks"], state["conv"], state["ssd"]))
    x = rmsnorm(params["ln_f"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"]["w"],
                            preferred_element_type=jnp.float32)
    else:
        logits = dense(params["unembed"], x).astype(jnp.float32)
    return logits, {"conv": conv_s, "ssd": ssd_s}
"""Shared model building blocks (pure-function style: params are dict
pytrees, every layer is `f(params, x, ...)`).

Conventions:
  * params are created by `init_*` functions taking a jax.random key;
  * all matmuls accumulate in float32 (`preferred_element_type`) and cast
    back to the activation dtype — standard bf16 training practice;
  * weights carry a `.sharding_hint` path convention instead: the sharding
    rules in repro.train.sharding key off parameter path names.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["dense", "init_dense", "rmsnorm", "init_rmsnorm", "rope",
           "embed", "init_embed", "gelu", "silu", "softmax_xent",
           "DTYPES"]

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
          "float16": jnp.float16}


def init_dense(key, d_in: int, d_out: int, dtype, bias: bool = False,
               scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    w = jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale
    p = {"w": w.astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype=dtype)
    return p


def dense(p, x):
    y = jnp.einsum("...i,io->...o", x, p["w"],
                   preferred_element_type=jnp.float32)
    if "b" in p:
        y = y + p["b"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_rmsnorm(d: int, dtype):
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def init_embed(key, vocab: int, d: int, dtype):
    w = jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02
    return {"w": w.astype(dtype)}


def embed(p, ids):
    return jnp.take(p["w"], ids, axis=0)


def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs
    # ang: (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1.astype(x.dtype), y2.astype(x.dtype)], axis=-1)


def gelu(x):
    return jax.nn.gelu(x.astype(jnp.float32), approximate=True).astype(x.dtype)


def silu(x):
    return (x.astype(jnp.float32) *
            jax.nn.sigmoid(x.astype(jnp.float32))).astype(x.dtype)


def softmax_xent(logits, labels, mask=None):
    """Mean token cross-entropy; logits (..., V) fp32-accumulated."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is None:
        return jnp.mean(nll)
    mask = mask.astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def scan_blocks_grouped(block_fn, carry, stacked_params, *, remat: bool,
                        group: int, n_layers: int):
    """Scan a layer stack with two-level (sqrt-L) remat.

    block_fn(bp, carry) -> carry.  With remat, layers are scanned in groups
    of `group`; only group inputs are saved persistently — each group's
    backward re-runs its layers (whose inputs then live transiently), and
    each layer is itself checkpointed so block internals are rematerialized.
    This keeps the persistent residual stack at L/group slices instead of L
    (critical at global-batch scale; see EXPERIMENTS.md §Perf).
    """
    import functools as _ft
    NP = jax.checkpoint_policies.nothing_saveable

    if not remat:
        def body(c, bp):
            return block_fn(bp, c), None
        carry, _ = jax.lax.scan(body, carry, stacked_params)
        return carry

    g = group if group and n_layers % group == 0 else 1
    if g == 1:
        def body(c, bp):
            fn = jax.checkpoint(block_fn, policy=NP)
            return fn(bp, c), None
        carry, _ = jax.lax.scan(body, carry, stacked_params)
        return carry

    G = n_layers // g
    grouped = jax.tree.map(lambda a: a.reshape((G, g) + a.shape[1:]),
                           stacked_params)

    @_ft.partial(jax.checkpoint, policy=NP)
    def group_fn(gbp, c):
        def inner(c2, bp):
            fn = jax.checkpoint(block_fn, policy=NP)
            return fn(bp, c2), None
        c, _ = jax.lax.scan(inner, c, gbp)
        return c

    def gbody(c, gbp):
        return group_fn(gbp, c), None

    carry, _ = jax.lax.scan(gbody, carry, grouped)
    return carry


def lm_loss_chunked(x, w, labels, mask=None, tied: bool = False,
                    chunk: int = 512):
    """Cross-entropy over (B, S, d) hidden states WITHOUT materializing the
    full (B, S, V) logits: scan over sequence chunks, rematerializing each
    chunk's logits in the backward pass.

    w: unembed weight (d, V), or embedding table (V, d) when tied=True.
    """
    B, S, d = x.shape
    chunk = min(chunk, S)
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.zeros((B, S), jnp.float32) if mask is None \
            else mask.astype(jnp.float32)
        mask = jnp.pad(jnp.ones((B, S), jnp.float32) if mask is None else m,
                       ((0, 0), (0, pad)))
    elif mask is None:
        mask = jnp.ones((B, S), jnp.float32)
    else:
        mask = mask.astype(jnp.float32)
    from ..train.meshctx import constrain_batch
    xc = constrain_batch(jnp.moveaxis(x.reshape(B, nc, chunk, d), 1, 0), 1)
    lc = constrain_batch(jnp.moveaxis(labels.reshape(B, nc, chunk), 1, 0), 1)
    mc = constrain_batch(jnp.moveaxis(mask.reshape(B, nc, chunk), 1, 0), 1)

    @jax.checkpoint
    def one(xi, li, mi):
        from ..train.meshctx import constrain_batch
        if tied:
            logits = jnp.einsum("bsd,vd->bsv", xi, w,
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bsd,dv->bsv", xi, w,
                                preferred_element_type=jnp.float32)
        logits = constrain_batch(logits, 0, model_dim=2)  # (B, chunk, V)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        nll = (logz - gold) * mi
        return jnp.sum(nll), jnp.sum(mi)

    def body(carry, xs):
        tot, cnt = carry
        s, c = one(*xs)
        return (tot + s, cnt + c), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.float32(0.0), jnp.float32(0.0)),
                                 (xc, lc, mc))
    return tot / jnp.maximum(cnt, 1.0)

"""Preconditioning subsystem: numeric incomplete factorization + facade.

The producer side of the paper's motivating scenario — triangular factors
for "preconditioners to sparse iterative solvers" — factored from the
user's matrix and wired into the transformed-SpTRSV operator pipeline:

    from repro.precond import Preconditioner, ic0, ilu0

    P = Preconditioner.ic0(A)        # factor + pair-tune + cached operators
    z = P(r)                         # z = M^-1 r (numpy or JAX, jit-safe)

`ic0`/`ilu0` alone return the raw factors (FactorResult) for callers that
manage their own operators.  The consumer side lives in `repro.iterative`
(jit-native Krylov drivers); docs/iterative.md walks the full pipeline.
"""
from .api import IdentityPreconditioner, Preconditioner
from .factorize import (FactorResult, FactorizationBreakdown, ic0, ilu0,
                        refactor)

__all__ = [
    "Preconditioner", "IdentityPreconditioner",
    "FactorResult", "FactorizationBreakdown", "ic0", "ilu0", "refactor",
]

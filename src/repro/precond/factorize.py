"""Numeric incomplete factorization on CSR: IC(0) and ILU(0).

This is the missing producer side of the paper's motivating scenario —
"SpTRSV is a building block to preconditioners for sparse iterative
solvers".  Everything downstream of this module already exists (transform
strategies, the width-bucketed schedule compiler, the engine registry, the
cached `TriangularOperator`); this module turns a user's *system matrix*
into the triangular factor(s) those layers consume:

    fac = ic0(A)      # SPD A            ->  L with pattern tril(A), A ~ L L^T
    fac = ilu0(A)     # general square A ->  unit-L and U on A's pattern

Both use the zero-fill ("level 0") pattern: the factor keeps exactly the
input's sparsity, which is what makes the preconditioner's triangular
solves as cheap as one SpMV — and what makes them SpTRSVs worth
transforming.

Vectorized up-looking sweeps
============================
Classic up-looking IC(0)/ILU(0) is a doubly-nested per-row/per-entry loop.
Here the sweep is vectorized with the same machinery the solver uses for
execution: the dependency DAG of the factor's strict-lower pattern is cut
into level sets (`sparse.levels.build_levels`), rows within a level are
numerically independent, and the only remaining order is *within* a row —
entry t of a row needs entries 0..t-1 of the same row.  So the sweep runs
`level x wave` — wave t updates the t-th strict-lower entry of every row of
the level at once — and every numeric statement is a flat numpy gather /
scatter over precomputed index arrays (built once from the pattern, O(pair
count), reused across diagonal-shift retries).

Breakdown & diagonal shifting
=============================
IC(0) breaks down when a pivot `A[i,i] - sum_k L[i,k]^2` is not positive
(possible even for SPD A), ILU(0) when a pivot `U[k,k]` is ~0.  Following
Manteuffel's shifted incomplete factorization, on breakdown the sweep
restarts on `A + alpha * diag(|A|)` with `alpha` growing geometrically from
`shift0` until the factorization completes; `FactorResult.shift` records
the alpha actually needed (0.0 in the common diagonally-dominant case).
`max_shift_attempts=0` disables shifting — breakdown then raises
`FactorizationBreakdown`.  Both factorizations share one declarative
ladder — `repro.core.resilience.RetryPolicy(max_attempts=max_shift_attempts,
scale0=shift0)` — so the retry semantics cannot drift between them.

`ic0` validates its input (symmetric pattern + values, positive diagonal)
and rejects non-SPD-shaped matrices with a ValueError; pass
`check_symmetric=False` to skip the O(nnz) check for trusted inputs.

The `Preconditioner` facade in `repro.precond.api` wires these factors into
paired, portfolio-tuned `TriangularOperator`s; the full walkthrough lives
in docs/iterative.md.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.resilience import PatternMismatchError, RetryPolicy
from ..sparse.csr import CSR, from_coo, same_pattern, tril
from ..sparse.levels import build_levels

__all__ = ["FactorResult", "FactorizationBreakdown", "ic0", "ilu0",
           "refactor"]


class FactorizationBreakdown(RuntimeError):
    """Incomplete factorization hit a non-positive / ~zero pivot and
    diagonal shifting was disabled or exhausted."""


@dataclasses.dataclass(frozen=True)
class FactorResult:
    """Output of ic0/ilu0: the factor(s) plus breakdown bookkeeping.

    kind:     "ic0" or "ilu0".
    L:        lower-triangular CSR factor, diagonal included.  For ic0 this
              is the incomplete Cholesky factor (A ~ L L^T); for ilu0 the
              unit-lower factor with its 1.0 diagonal stored explicitly.
    U:        upper-triangular CSR factor for ilu0 (A ~ L U); None for ic0
              (the backward sweep solves with L^T via transpose=True).
    shift:    the diagonal shift alpha that made the factorization succeed
              (0.0 when no breakdown occurred).
    attempts: number of factorization sweeps run (1 = no breakdown).
    plan:     the pattern-only preprocessing (_IC0Plan / _ILU0Plan) the
              numeric sweep ran over.  Kept so `refactor` can re-run the
              sweep for new values on the same pattern without re-deriving
              the index arrays (the refactorization fast path,
              docs/refactorization.md).
    """

    kind: str
    L: CSR
    U: CSR | None
    shift: float
    attempts: int
    plan: object | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n(self) -> int:
        return self.L.n_rows

    def __repr__(self) -> str:  # pragma: no cover
        return (f"FactorResult(kind={self.kind!r}, n={self.n}, "
                f"nnz_L={self.L.nnz}, "
                f"nnz_U={self.U.nnz if self.U is not None else None}, "
                f"shift={self.shift}, attempts={self.attempts})")


# -- pattern analysis (shared by both factorizations) -------------------------


def _ragged_arange(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenate [arange(s, s+c) for s, c in zip(starts, counts)]."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    rep_starts = np.repeat(starts, counts)
    offs = np.repeat(np.cumsum(counts) - counts, counts)
    return rep_starts + (np.arange(total) - offs)


def _positions_of(pat: CSR, rows: np.ndarray, cols: np.ndarray):
    """(positions, found) of entries (rows[i], cols[i]) in pat's data order.

    CSR with sorted rows makes the composite key `row * n_cols + col`
    globally ascending, so membership is one searchsorted over all queries.
    """
    prow = np.repeat(np.arange(pat.n_rows), pat.row_nnz())
    comp = prow * pat.n_cols + pat.indices
    key = rows * pat.n_cols + cols
    pos = np.searchsorted(comp, key)
    pos_c = np.minimum(pos, comp.shape[0] - 1)
    found = (pos < comp.shape[0]) & (comp[pos_c] == key)
    return pos_c, found


def _diag_positions(pat: CSR, what: str) -> np.ndarray:
    """Position of each row's diagonal entry; every row must have one."""
    n = pat.n_rows
    pos, found = _positions_of(pat, np.arange(n), np.arange(n))
    if not found.all():
        missing = int(np.flatnonzero(~found)[0])
        raise ValueError(f"{what}: row {missing} has no diagonal entry in "
                         f"the sparsity pattern (zero-fill factorization "
                         f"needs a full diagonal)")
    return pos


def _check_symmetric(A: CSR, rtol: float = 1e-10) -> None:
    """Reject matrices that cannot be SPD: asymmetric pattern or values,
    or a non-positive diagonal entry."""
    if A.n_rows != A.n_cols:
        raise ValueError(f"ic0 needs a square matrix, got {A.shape}")
    At = A.transpose()
    sym = (A.indptr.shape == At.indptr.shape
           and np.array_equal(A.indptr, At.indptr)
           and np.array_equal(A.indices, At.indices)
           and np.allclose(A.data, At.data,
                           rtol=rtol, atol=rtol * max(1.0, float(
                               np.abs(A.data).max(initial=0.0)))))
    if not sym:
        raise ValueError(
            "ic0 needs a symmetric (SPD) matrix: pattern or values are not "
            "symmetric.  Pass the FULL matrix, not a triangle (see "
            "sparse.generators.poisson2d_spd / random_spd); use ilu0 for "
            "general square matrices.")
    d = A.diagonal_fast()
    if (d <= 0).any():
        i = int(np.flatnonzero(d <= 0)[0])
        raise ValueError(f"ic0: diagonal entry {i} is {d[i]:g} <= 0 — the "
                         f"matrix cannot be SPD")


def _shifted(pat_vals: np.ndarray, dpos: np.ndarray, alpha: float,
             base: np.ndarray) -> np.ndarray:
    """Values with the diagonal shifted: d += alpha * base."""
    vals = pat_vals.copy()
    vals[dpos] += alpha * base
    return vals


def _row_scale(pat: CSR, vals: np.ndarray) -> np.ndarray:
    """max |value| per row — the magnitude pivots are compared against.

    Scaling breakdown checks by the row (not the diagonal itself) matters:
    a ~zero diagonal in a row of O(1) entries must count as breakdown, and
    `d <= rtol * |d|` never fires.
    """
    # every row is non-empty (diagonal presence is validated first)
    return np.maximum.reduceat(np.abs(vals), pat.indptr[:-1])


def _shift_base(diag: np.ndarray, amax: float) -> np.ndarray:
    """Per-row shift unit: |A_ii|, or the matrix scale where the diagonal
    is degenerate (shifting a ~zero diagonal by multiples of itself would
    never cure the breakdown)."""
    base = np.abs(diag)
    floor = 1e-8 * max(amax, 1e-300)
    return np.where(base > floor, base, max(amax, 1.0))


def _wave_of(pat: CSR) -> tuple[np.ndarray, np.ndarray]:
    """(local index of each entry within its row, row id of each entry)."""
    rows = np.repeat(np.arange(pat.n_rows), pat.row_nnz())
    return np.arange(pat.nnz) - pat.indptr[rows], rows


# -- IC(0) --------------------------------------------------------------------


class _IC0Plan:
    """Pattern-only preprocessing for IC(0) on `low = tril(A)`.

    For every strict-lower entry p = (i, j), the update term is
    sum over k in cols(i) /\\ cols(j), k < j of L[i,k] * L[j,k]; the plan
    stores one (p, q, t) triple per product, where q is the position of
    L[i,k] (same row, earlier wave) and t the position of L[j,k] (earlier
    level, final).  Triples are bucketed by the (level, wave) at which q
    becomes final, so the numeric sweep scatter-adds each product exactly
    once, right after its q is computed.
    """

    def __init__(self, low: CSR):
        self.low = low
        n = low.n_rows
        self.dpos = _diag_positions(low, "ic0")
        if not (low.indices[self.dpos] == np.arange(n)).all():
            raise AssertionError("tril pattern must end rows on the diagonal")
        self.levels = build_levels(low)
        wave, rows = _wave_of(low)
        self.wave, self.rows_of = wave, rows
        self.n_off_of_row = low.row_nnz() - 1   # diag is each row's last
        offdiag = np.flatnonzero(low.indices < rows)        # strict lower
        self.offdiag = offdiag
        # candidate products: q runs over the entries of row(p) before p
        counts = wave[offdiag]                               # q-count per p
        pp = np.repeat(offdiag, counts)
        qq = _ragged_arange(low.indptr[rows[offdiag]], counts)
        jj = low.indices[pp]                                 # col of p
        kk = low.indices[qq]                                 # col of q
        tt, found = _positions_of(low, jj, kk)               # L[j, k]?
        pp, qq, tt = pp[found], qq[found], tt[found]
        # bucket by (level of q's row, wave of q): ready-order of q
        lvl_q = self.levels.level_of[rows[qq]]
        key = lvl_q * (int(wave.max(initial=0)) + 1) + wave[qq]
        order = np.argsort(key, kind="stable")
        self.pp, self.qq, self.tt = pp[order], qq[order], tt[order]
        self.key_sorted = key[order]

    def entries_at(self, lvl: int, w: int) -> np.ndarray:
        """Strict-lower positions at wave w of level lvl's rows."""
        rows = self.levels.rows_in_level(lvl)
        rows = rows[self.n_off_of_row[rows] > w]        # rows deep enough
        return self.low.indptr[rows] + w

    def pairs_at(self, lvl: int, w: int):
        key = lvl * self.max_wave_key + w
        lo = np.searchsorted(self.key_sorted, key)
        hi = np.searchsorted(self.key_sorted, key + 1)
        return self.pp[lo:hi], self.qq[lo:hi], self.tt[lo:hi]

    @property
    def max_wave_key(self) -> int:
        return int(self.wave.max(initial=0)) + 1


def _ic0_sweep(plan: _IC0Plan, vals: np.ndarray,
               breakdown_rtol: float) -> np.ndarray:
    """One numeric IC(0) pass over shifted input values `vals` (in tril
    pattern order).  Returns factor values or raises FactorizationBreakdown.
    """
    low, dpos = plan.low, plan.dpos
    data = np.zeros_like(vals)
    acc = np.zeros_like(vals)           # accumulated sum_k L[i,k] L[j,k]
    scale = _row_scale(low, vals)
    for lvl in range(plan.levels.num_levels):
        rows = plan.levels.rows_in_level(lvl)
        depth = int(plan.n_off_of_row[rows].max(initial=0))
        for w in range(depth):
            p = plan.entries_at(lvl, w)
            data[p] = (vals[p] - acc[p]) / data[dpos[low.indices[p]]]
            pp, qq, tt = plan.pairs_at(lvl, w)
            if pp.size:
                np.add.at(acc, pp, data[qq] * data[tt])
        # diagonal: d_i^2 = A[i,i] - sum_k L[i,k]^2
        sq = np.zeros(rows.shape[0])
        lo, hi = low.indptr[rows], plan.dpos[rows]
        seg = _ragged_arange(lo, hi - lo)
        np.add.at(sq, np.repeat(np.arange(rows.shape[0]), hi - lo),
                  data[seg] ** 2)
        d2 = vals[dpos[rows]] - sq
        bad = d2 <= breakdown_rtol * scale[rows]
        if bad.any():
            i = int(rows[np.flatnonzero(bad)[0]])
            raise FactorizationBreakdown(
                f"ic0: non-positive pivot at row {i} "
                f"(d^2 = {d2[np.flatnonzero(bad)[0]]:.3e})")
        data[dpos[rows]] = np.sqrt(d2)
    return data


def ic0(A: CSR, *, shift0: float = 1e-3, max_shift_attempts: int = 20,
        breakdown_rtol: float = 1e-12,
        check_symmetric: bool = True) -> FactorResult:
    """Incomplete Cholesky with zero fill: L on tril(A)'s pattern, A ~ L L^T.

    A:        the FULL symmetric positive-definite matrix (both triangles).
    shift0:   first diagonal shift tried after a breakdown; doubles per
              retry (Manteuffel shifting, see module doc).
    max_shift_attempts: retries before giving up (0 disables shifting).
    breakdown_rtol:     pivot d^2 <= rtol * |A[i,i]| counts as breakdown.
    check_symmetric:    reject asymmetric / non-positive-diagonal input.

    Returns a FactorResult with `L` (diagonal included) and `U=None`; apply
    the preconditioner as M^-1 = (L L^T)^-1 via a forward solve with L and a
    backward solve with transpose=True (repro.precond.Preconditioner does
    exactly this over cached TriangularOperators).
    """
    if check_symmetric:
        _check_symmetric(A)
    elif A.n_rows != A.n_cols:
        raise ValueError(f"ic0 needs a square matrix, got {A.shape}")
    low = tril(A)
    plan = _IC0Plan(low)
    base = _shift_base(low.data[plan.dpos],
                       float(np.abs(low.data).max(initial=0.0)))
    data, alpha, attempts = RetryPolicy(
        max_attempts=max_shift_attempts, scale0=shift0).run(
        lambda a: _ic0_sweep(plan, _shifted(low.data, plan.dpos, a, base),
                             breakdown_rtol),
        retry_on=(FactorizationBreakdown,))
    L = CSR(indptr=low.indptr, indices=low.indices, data=data,
            shape=low.shape)
    return FactorResult(kind="ic0", L=L, U=None, shift=alpha,
                        attempts=attempts, plan=plan)


# -- ILU(0) -------------------------------------------------------------------


class _ILU0Plan:
    """Pattern-only preprocessing for ILU(0) on A's full pattern.

    Row-wise IKJ elimination: for row i, for each strict-lower position
    p = (i, k) in column order, `w[k] /= U[k,k]` then `w[j] -= w[k] U[k,j]`
    for every j > k present in BOTH row k (upper part) and row i.  The plan
    stores one (p, u, tgt) triple per such update — u the position of
    U[k,j], tgt the position of (i,j) — bucketed by p's wave (its local
    index among row i's strict-lower entries), because row k lives in an
    earlier level and is final when row i is processed.
    """

    def __init__(self, pat: CSR):
        if pat.n_rows != pat.n_cols:
            raise ValueError(f"ilu0 needs a square matrix, got {pat.shape}")
        self.pat = pat
        n = pat.n_rows
        self.dpos = _diag_positions(pat, "ilu0")
        _, rows = _wave_of(pat)
        self.rows_of = rows
        lower = np.flatnonzero(pat.indices < rows)
        self.lower = lower
        self.lower_wave = lower - pat.indptr[rows[lower]]  # cols sorted =>
        #                      strict-lower entries are the row's first ones
        self.levels = build_levels(tril(pat))
        # update triples for each lower entry p = (i, k)
        kk = pat.indices[lower]
        u_lo = self.dpos[kk] + 1                 # upper entries of row k
        u_hi = pat.indptr[kk + 1]
        counts = u_hi - u_lo
        pp = np.repeat(lower, counts)
        uu = _ragged_arange(u_lo, counts)
        jj = pat.indices[uu]
        tgt, found = _positions_of(pat, rows[pp], jj)
        pp, uu, tgt = pp[found], uu[found], tgt[found]
        lvl_p = self.levels.level_of[rows[pp]]
        self.max_wave_key = int(self.lower_wave.max(initial=0)) + 1
        key = lvl_p * self.max_wave_key + (pp - pat.indptr[rows[pp]])
        order = np.argsort(key, kind="stable")
        self.pp, self.uu, self.tgt = pp[order], uu[order], tgt[order]
        self.key_sorted = key[order]
        self.n_lower_of_row = self.dpos - pat.indptr[:-1]  # strict-lower count

    def entries_at(self, lvl: int, w: int) -> np.ndarray:
        rows = self.levels.rows_in_level(lvl)
        rows = rows[self.n_lower_of_row[rows] > w]
        return self.pat.indptr[rows] + w

    def updates_at(self, lvl: int, w: int):
        key = lvl * self.max_wave_key + w
        lo = np.searchsorted(self.key_sorted, key)
        hi = np.searchsorted(self.key_sorted, key + 1)
        return self.pp[lo:hi], self.uu[lo:hi], self.tgt[lo:hi]


def _ilu0_sweep(plan: _ILU0Plan, vals: np.ndarray,
                breakdown_rtol: float) -> np.ndarray:
    """One numeric ILU(0) pass; `vals` in A's pattern order (shifted).
    Factors in place: on return, strict-lower positions hold L (unit
    diagonal implicit), diagonal + upper positions hold U."""
    pat, dpos = plan.pat, plan.dpos
    data = vals.copy()
    scale = _row_scale(pat, vals)
    for lvl in range(plan.levels.num_levels):
        rows = plan.levels.rows_in_level(lvl)
        depth = int(plan.n_lower_of_row[rows].max(initial=0))
        for w in range(depth):
            p = plan.entries_at(lvl, w)
            k = pat.indices[p]
            data[p] = data[p] / data[dpos[k]]
            pp, uu, tgt = plan.updates_at(lvl, w)
            if pp.size:
                # one eliminating entry per row per wave => tgt disjoint
                data[tgt] = data[tgt] - data[pp] * data[uu]
        d = data[dpos[rows]]
        bad = np.abs(d) <= breakdown_rtol * scale[rows]
        if bad.any():
            i = int(rows[np.flatnonzero(bad)[0]])
            raise FactorizationBreakdown(
                f"ilu0: ~zero pivot at row {i} (U[{i},{i}] = "
                f"{d[np.flatnonzero(bad)[0]]:.3e})")
    return data


def ilu0(A: CSR, *, shift0: float = 1e-3, max_shift_attempts: int = 20,
         breakdown_rtol: float = 1e-14) -> FactorResult:
    """Incomplete LU with zero fill on A's pattern: A ~ L U, L unit-lower.

    Up-looking IKJ elimination restricted to A's sparsity (no fill-in):
    the defining property is (L U)[i, j] == A[i, j] exactly for every
    (i, j) in A's pattern.  Breakdown (a ~zero pivot) triggers the same
    geometric diagonal-shift retry as `ic0`.

    Returns a FactorResult with `L` (unit diagonal stored explicitly, so
    it solves through the standard lower operator) and `U` (diagonal
    included, solved with side="upper").
    """
    plan = _ILU0Plan(A)
    base = _shift_base(A.data[plan.dpos],
                       float(np.abs(A.data).max(initial=0.0)))
    data, alpha, attempts = RetryPolicy(
        max_attempts=max_shift_attempts, scale0=shift0).run(
        lambda a: _ilu0_sweep(plan, _shifted(A.data, plan.dpos, a, base),
                              breakdown_rtol),
        retry_on=(FactorizationBreakdown,))
    L, U = _ilu0_split(A, data)
    return FactorResult(kind="ilu0", L=L, U=U, shift=alpha,
                        attempts=attempts, plan=plan)


def _ilu0_split(pat: CSR, data: np.ndarray) -> tuple[CSR, CSR]:
    """Split in-place-factored values (strict-lower = L, diag+upper = U)
    into the two triangular factor CSRs."""
    n = pat.n_rows
    rows = np.repeat(np.arange(n), pat.row_nnz())
    low_mask = pat.indices < rows
    up_mask = pat.indices >= rows
    L = from_coo(np.concatenate([rows[low_mask], np.arange(n)]),
                 np.concatenate([pat.indices[low_mask], np.arange(n)]),
                 np.concatenate([data[low_mask], np.ones(n)]),
                 pat.shape, sum_duplicates=False)
    U = from_coo(rows[up_mask], pat.indices[up_mask], data[up_mask],
                 pat.shape, sum_duplicates=False)
    return L, U


# -- pattern-frozen refactorization -------------------------------------------


def refactor(fac: FactorResult, A_new: CSR, *, shift0: float = 1e-3,
             max_shift_attempts: int = 20,
             breakdown_rtol: float | None = None) -> FactorResult:
    """Numeric-only re-factorization of a new matrix on the SAME pattern.

    Re-runs the vectorized ic0/ilu0 value sweep over the pattern plan
    already carried by `fac` — level sets, update-pair index arrays and
    diagonal positions are all reused untouched, so per time-step cost is
    the numeric sweep alone.  The diagonal-shift retry ladder applies as in
    the fresh factorization (each refactorization gets its own shift).

    A_new whose pattern differs from the frozen one — for ic0 the pattern
    of tril(A_new), for ilu0 the full pattern — raises a typed
    PatternMismatchError: rebuild with ic0()/ilu0() instead.  A `fac`
    without a plan (e.g. unpickled from an old artifact) raises ValueError.

    breakdown_rtol: None picks the kind's fresh-factorization default
    (1e-12 for ic0, 1e-14 for ilu0).

    Values are NOT re-validated for symmetry (ic0's SPD check): the
    pattern is frozen and per-step inputs are trusted — pass A_new through
    `ic0(A_new)` if it needs the full validation.
    """
    plan = fac.plan
    if plan is None:
        raise ValueError(
            f"FactorResult(kind={fac.kind!r}) carries no pattern plan "
            "(stale artifact?) — run ic0()/ilu0() on the new matrix instead")
    where = f"refactor[{fac.kind}](n={fac.n})"
    if fac.kind == "ic0":
        rtol = 1e-12 if breakdown_rtol is None else breakdown_rtol
        low = tril(A_new)
        if not same_pattern(low, plan.low):
            raise PatternMismatchError(
                "tril(A_new) pattern differs from the frozen ic0 pattern; "
                "re-run ic0()", where=where, detail="lower-triangle drift")
        base = _shift_base(low.data[plan.dpos],
                           float(np.abs(low.data).max(initial=0.0)))
        data, alpha, attempts = RetryPolicy(
            max_attempts=max_shift_attempts, scale0=shift0).run(
            lambda a: _ic0_sweep(plan, _shifted(low.data, plan.dpos, a, base),
                                 rtol),
            retry_on=(FactorizationBreakdown,))
        L = CSR(indptr=low.indptr, indices=low.indices, data=data,
                shape=low.shape)
        return FactorResult(kind="ic0", L=L, U=None, shift=alpha,
                            attempts=attempts, plan=plan)
    if fac.kind == "ilu0":
        rtol = 1e-14 if breakdown_rtol is None else breakdown_rtol
        if not same_pattern(A_new, plan.pat):
            raise PatternMismatchError(
                "A_new pattern differs from the frozen ilu0 pattern; "
                "re-run ilu0()", where=where, detail="pattern drift")
        base = _shift_base(A_new.data[plan.dpos],
                           float(np.abs(A_new.data).max(initial=0.0)))
        data, alpha, attempts = RetryPolicy(
            max_attempts=max_shift_attempts, scale0=shift0).run(
            lambda a: _ilu0_sweep(plan,
                                  _shifted(A_new.data, plan.dpos, a, base),
                                  rtol),
            retry_on=(FactorizationBreakdown,))
        L, U = _ilu0_split(A_new, data)
        return FactorResult(kind="ilu0", L=L, U=U, shift=alpha,
                            attempts=attempts, plan=plan)
    raise ValueError(f"unknown factorization kind {fac.kind!r}")

"""Preconditioner facade: factor A, tune the pair, serve M^-1 applications.

One call takes a user's system matrix to a ready preconditioner whose two
triangular sweeps run through the paper's transformed SpTRSV pipeline:

    P = Preconditioner.ic0(A, tune="auto")     # SPD:     M = L L^T
    P = Preconditioner.ilu0(A, tune="auto")    # general: M = L U
    z = P(r)                                   # z = M^-1 r, (n,) or (n, k)

Under the hood:

1. `repro.precond.factorize` produces the numeric zero-fill factor(s),
   with breakdown detection + diagonal shifting (`P.factors` records the
   shift actually applied).
2. The strategy portfolio tunes the PAIR jointly
   (`StrategyPortfolio.tune_pair`): both oriented sweeps are scored per
   candidate strategy and one strategy minimizing the summed pair cost is
   picked — a preconditioner application is always both sweeps, so
   per-side winners that disagree would optimize half the cost.  The pair
   decision is memoized under the SYSTEM matrix's fingerprint (plus the
   tuning configuration), so re-preconditioning the same A skips straight
   to operator construction.
3. Two cached `TriangularOperator`s are built with the winning strategy —
   forward `L`, backward `L^T` (ic0, via transpose=True) or `U` (ilu0,
   via side="upper") — sharing the operator memory/disk cache keyed by the
   factor fingerprints.

`P(r)` dispatches on the input: numpy in, float64 numpy out (host path,
optional iterative refinement); JAX array (or tracer) in, JAX array out
through `device_apply` — the whole M^-1 application as ONE traceable
device computation (compiled preamble + schedule per sweep, no host
callbacks), so the preconditioner drops straight into the jit-native
Krylov drivers of `repro.iterative` (see docs/iterative.md).
"""
from __future__ import annotations

import collections
import dataclasses as _dc
import hashlib
import threading

import numpy as np

from ..solver.operator import (TriangularOperator, compose_sweep_fn,
                               matrix_fingerprint, orient_lower)
from ..sparse.csr import CSR
from . import factorize
from .factorize import FactorResult

__all__ = ["Preconditioner", "IdentityPreconditioner"]


class Preconditioner:
    """Paired triangular operators applying M^-1 = (L L^T)^-1 or (L U)^-1.

    Construct via the classmethods (`ic0`, `ilu0`, or `from_factors` for a
    factor computed elsewhere); the constructor itself just binds the
    pieces.  Attributes:

    factors:  the FactorResult (factor CSRs, shift, attempts).
    forward:  TriangularOperator for the L sweep.
    backward: TriangularOperator for the L^T / U sweep.
    report:   slim PairReport when tune="auto" ran, else None.
    strategy: the strategy label both operators were compiled with.
    """

    # (system fingerprint, kind, config) -> (Strategy, slim PairReport):
    # re-preconditioning the same A re-uses the pair decision without
    # re-running the portfolio (the compiled operators are cached
    # separately, under the FACTOR fingerprints, by TriangularOperator).
    # Bounded LRU for the same reason as TriangularOperator._memory_cache:
    # a long-lived server over many matrices must not accumulate reports
    # forever
    _pair_decisions: collections.OrderedDict = collections.OrderedDict()
    _pair_decisions_max: int = 16
    # memo mutations must be atomic under concurrent preconditioner
    # construction (serving-tier background tuning); the tuning itself
    # runs OUTSIDE the lock — two racing builders may both tune, but the
    # memo never interleaves a move_to_end with an eviction
    _pair_lock = threading.RLock()

    def __init__(self, factors: FactorResult, forward: TriangularOperator,
                 backward: TriangularOperator, report=None):
        self.factors = factors
        self.forward = forward
        self.backward = backward
        self.report = report
        self.strategy = forward.strategy
        self._device_fns: dict = {}

    # -- construction ---------------------------------------------------------
    @classmethod
    def ic0(cls, A: CSR, tune="auto", **kwargs) -> "Preconditioner":
        """Incomplete-Cholesky preconditioner M = L L^T for SPD A.

        Factorization knobs (shift0, max_shift_attempts, breakdown_rtol,
        check_symmetric) ride in `factor_kwargs`; everything else is
        forwarded to TriangularOperator.from_csr — see `from_factors`.
        """
        factor_kwargs = kwargs.pop("factor_kwargs", None) or {}
        fac = factorize.ic0(A, **factor_kwargs)
        return cls.from_factors(fac, tune=tune, system=A, **kwargs)

    @classmethod
    def ilu0(cls, A: CSR, tune="auto", **kwargs) -> "Preconditioner":
        """Incomplete-LU preconditioner M = L U for general square A."""
        factor_kwargs = kwargs.pop("factor_kwargs", None) or {}
        fac = factorize.ilu0(A, **factor_kwargs)
        return cls.from_factors(fac, tune=tune, system=A, **kwargs)

    @classmethod
    def from_factors(cls, fac: FactorResult, tune="auto", *, system=None,
                     chunk: int = 256, max_deps: int = 16, dtype=np.float32,
                     engine=None, mesh=None, mesh_axis: str = "model",
                     cache: bool = True, cache_dir=None,
                     cost_model=None,
                     measure_top_k: int = 0) -> "Preconditioner":
        """Build the operator pair for an existing FactorResult.

        tune:   "auto" — joint pair tuning through the strategy portfolio
                (memoized per system/config when `system` is given); a
                stable strategy name or Strategy instance — both operators
                use it directly.
        system: the original matrix A (fingerprint key for the pair-
                decision memo; optional — without it "auto" still tunes,
                just never memoizes).
        mesh/mesh_axis: a jax Mesh serves BOTH sweeps through the sharded
                engine over `mesh_axis`, so M^-1 applications (host or
                device_apply) run under one mesh with no host round trips
                between the two sweeps (docs/distributed.md).  Mutually
                exclusive with engine=.
        Remaining arguments match TriangularOperator.from_csr.
        """
        if mesh is not None:
            from ..solver.engines import resolve_engine
            engine = resolve_engine(engine, mesh=mesh, mesh_axis=mesh_axis)
        report = None
        if tune == "auto":
            tune, report = cls._pair_decision(
                fac, system, chunk=chunk, max_deps=max_deps, dtype=dtype,
                engine=engine, cost_model=cost_model,
                measure_top_k=measure_top_k)
        op_kw = dict(chunk=chunk, max_deps=max_deps, dtype=dtype,
                     engine=engine, cache=cache, cache_dir=cache_dir)
        if fac.kind == "ic0":
            forward = TriangularOperator.from_csr(fac.L, tune, side="lower",
                                                  transpose=False, **op_kw)
            backward = TriangularOperator.from_csr(fac.L, tune, side="lower",
                                                   transpose=True, **op_kw)
        else:
            forward = TriangularOperator.from_csr(fac.L, tune, side="lower",
                                                  transpose=False, **op_kw)
            backward = TriangularOperator.from_csr(fac.U, tune, side="upper",
                                                   transpose=False, **op_kw)
        return cls(fac, forward, backward, report=report)

    @classmethod
    def _pair_decision(cls, fac: FactorResult, system, *, chunk, max_deps,
                       dtype, engine, cost_model, measure_top_k):
        """Joint pair tuning, memoized under the system fingerprint.

        Model ranking comes from `StrategyPortfolio.tune_pair`; when
        `measure_top_k > 0` the model's top-k candidates PLUS the
        `no_rewriting` baseline are re-timed through the COMPOSED device
        pipeline (flip + compiled T-factor preamble + schedule, both
        sweeps back to back) — i.e. exactly what a Krylov loop will
        execute, preamble realization included.  Measuring the served
        pipeline (not the host preamble) matters: a transform whose
        T-factor is expensive can model-rank well yet lose end to end,
        and including the baseline guarantees the pick is never slower
        than `no_rewriting` up to timer noise.
        """
        from ..core.portfolio import (StrategyPortfolio,
                                      default_cost_model_for)
        from ..solver.engines import resolve_engine
        eng = resolve_engine(engine)
        if cost_model is None:
            # same defaulting as TriangularOperator.from_csr: a pair that
            # will serve sharded sweeps is tuned against the cost model
            # that charges the per-step collective
            cost_model = default_cost_model_for(eng)
        key = None
        if system is not None:
            # like TriangularOperator.from_csr's cache cfg: the decision
            # is engine-independent UNLESS measured re-ranking ran — then
            # the pick depends on which engine was timed (cache_token:
            # sharded engines over different meshes time differently)
            cfg = (fac.kind, chunk, max_deps, np.dtype(dtype).name,
                   measure_top_k,
                   (getattr(eng, "cache_token", lambda: eng.name)()
                    if measure_top_k > 0 else None),
                   None if cost_model is None
                   else tuple(sorted(_dc.asdict(cost_model).items())))
            key = matrix_fingerprint(system) + "-" + hashlib.sha256(
                repr(cfg).encode()).hexdigest()[:16]
            with cls._pair_lock:
                hit = cls._pair_decisions.get(key)
                if hit is not None:
                    cls._pair_decisions.move_to_end(key)
                    return hit
        fwd_sys, _ = orient_lower(fac.L, "lower", False)
        if fac.kind == "ic0":
            bwd_sys, bwd_rev = orient_lower(fac.L, "lower", True)
        else:
            bwd_sys, bwd_rev = orient_lower(fac.U, "upper", False)
        tuner = StrategyPortfolio(chunk=chunk, max_deps=max_deps,
                                  dtype=dtype, cost_model=cost_model,
                                  measure_top_k=0, engine=engine)
        pair = tuner.tune_pair(fwd_sys, bwd_sys)
        best_label = pair.best_label
        if measure_top_k > 0:
            best_label = cls._measure_pair(pair, bwd_rev, engine=engine,
                                           chunk=chunk, max_deps=max_deps,
                                           dtype=dtype,
                                           top_k=measure_top_k)
        best = next(c for c in pair.fwd.candidates if c.label == best_label)
        decision = (best.strategy, pair.slim())
        if key is not None:
            with cls._pair_lock:
                cls._pair_decisions[key] = decision
                cls._pair_decisions.move_to_end(key)
                while len(cls._pair_decisions) > cls._pair_decisions_max:
                    cls._pair_decisions.popitem(last=False)
        return decision

    @staticmethod
    def _measure_pair(pair, bwd_reversed: bool, *, engine, chunk, max_deps,
                      dtype, top_k: int, reps: int = 3) -> str:
        """Re-rank candidate labels by measured wall time of one composed
        M^-1 application through the device pipeline; updates
        pair.combined in place and returns the winner.  The no_rewriting
        baseline is always measured (guardrail, see _pair_decision)."""
        import time as _time
        import jax
        import jax.numpy as jnp
        from ..solver.engines import compile_source, resolve_engine
        from ..solver.levelset import to_device
        from ..solver.schedule import schedule_for_preamble
        eng = resolve_engine(engine)
        labels = [c["label"] for c in pair.combined[:top_k]]
        if "no_rewriting" not in labels and any(
                c["label"] == "no_rewriting" for c in pair.combined):
            labels.append("no_rewriting")
        by_label_f = {c.label: c for c in pair.fwd.candidates
                      if c.error is None}
        by_label_b = {c.label: c for c in pair.bwd.candidates
                      if c.error is None}

        def side_fn(cand, reversed_):
            psched, src, row_pos = schedule_for_preamble(
                cand.ts, chunk=chunk, max_deps=max_deps,
                dtype=np.dtype(dtype))
            # host-lowering engines take the host schedules directly (the
            # same engines.compile_source branch the serving path's
            # _compiled_fn/_preamble_host takes)
            main_fn = eng.compile(compile_source(
                eng, cand.sched, lambda: to_device(cand.sched)))
            pre = None
            if psched is not None:
                pre = eng.compile(compile_source(
                    eng, psched, lambda: to_device(psched)))
            # the SAME composition production runs (device_solve_fn):
            # what gets timed is what gets served
            return compose_sweep_fn(main_fn, cand.sched.dtype, pre, src,
                                    row_pos, reversed_)

        n = pair.fwd.matrix["n"]
        r = jnp.asarray(np.random.default_rng(0).standard_normal(n),
                        dtype=np.dtype(dtype))
        measured = {}
        for label in labels:
            f = side_fn(by_label_f[label], False)
            g = side_fn(by_label_b[label], bwd_reversed)
            apply_fn = jax.jit(lambda v: g(f(v)))
            jax.block_until_ready(apply_fn(r))      # compile outside timer
            best = float("inf")
            for _ in range(reps):
                t0 = _time.perf_counter()
                jax.block_until_ready(apply_fn(r))
                best = min(best, _time.perf_counter() - t0)
            measured[label] = best * 1e6
        for c in pair.combined:
            if c["label"] in measured:
                # total_us becomes the measured composed-apply time;
                # fwd_us/bwd_us stay as the per-side MODEL estimates
                c.update(measured=True,
                         total_us=round(measured[c["label"]], 1))
        pair.combined.sort(key=lambda c: (not c["measured"], c["total_us"]))
        winner = min(measured, key=measured.get)
        pair.best_label = winner
        return winner

    @classmethod
    def clear_pair_decisions(cls) -> None:
        with cls._pair_lock:
            cls._pair_decisions.clear()

    def refactor(self, new_A: CSR, **factor_kwargs) -> "Preconditioner":
        """Numeric-only re-preconditioning for a new A on the SAME pattern.

        The refactorization fast path for time-stepping / Newton outer
        loops (docs/refactorization.md): re-runs only the ic0/ilu0 value
        sweep over the frozen pattern plan (`factorize.refactor`), then
        re-binds both triangular operators in place through
        `TriangularOperator.update_values` — pair tuning, level analysis,
        transformations, schedules and compiled engine executables are all
        reused.  Mutates this preconditioner and returns self.

        A pattern-changing A raises PatternMismatchError (build a fresh
        Preconditioner instead); `factor_kwargs` forwards shift0 /
        max_shift_attempts / breakdown_rtol to `factorize.refactor`.
        """
        fac = factorize.refactor(self.factors, new_A, **factor_kwargs)
        self.forward.update_values(fac.L)
        self.backward.update_values(fac.L if fac.kind == "ic0" else fac.U)
        self.factors = fac
        # composed device pipelines close over the old payloads' staged
        # schedules — drop them so the next device_apply recomposes
        self._device_fns.clear()
        return self

    # -- application ----------------------------------------------------------
    @property
    def n(self) -> int:
        return self.factors.n

    @property
    def operators(self) -> tuple:
        """(forward, backward) TriangularOperator pair."""
        return self.forward, self.backward

    def apply(self, r: np.ndarray, *, engine=None, max_refine: int = 0,
              refine_tol: float = 1e-10, health=None) -> np.ndarray:
        """z = M^-1 r on host: forward sweep then backward sweep.

        Refinement defaults OFF (max_refine=0): M^-1 is approximate by
        construction, and a fixed slightly-perturbed M only changes the
        Krylov convergence rate, not the attainable outer residual.  The
        sweeps themselves then run fp64-copy-free in the schedule dtype;
        only the returned z is cast up, preserving the facade's
        numpy-in / float64-numpy-out contract (module doc).

        health: solve-path health policy (HealthPolicy, a named level, or
        None for the REPRO_HEALTH_CHECKS environment default), applied to
        BOTH sweeps — a non-finite r raises a typed NumericalHealthError
        before any device work, a poisoned sweep raises / repairs / falls
        back per the policy, and engine failures walk the registry
        fallback chain (see TriangularOperator.solve, docs/robustness.md).
        Note the residual level of "strict" checks each triangular sweep
        against its own factor, not M^-1 against A — that approximation
        gap is by construction.
        """
        z = self.forward.solve(r, engine=engine, max_refine=max_refine,
                               refine_tol=refine_tol, health=health)
        z = self.backward.solve(z, engine=engine, max_refine=max_refine,
                                refine_tol=refine_tol, health=health)
        return np.asarray(z, dtype=np.float64)

    def device_apply(self, engine=None):
        """The full M^-1 application as a pure JAX callable: forward and
        backward device pipelines (reversal + compiled T-factor preamble +
        compiled schedule, see TriangularOperator.device_solve_fn)
        composed back to back.  No host callbacks — safe inside
        jit/while_loop hot paths regardless of thread-local dtype config,
        which pure_callback is not (XLA may run callbacks on worker
        threads where a scoped enable_x64() does not apply)."""
        key = ("device_apply", None if engine is None else str(engine))
        fn = self._device_fns.get(key)
        if fn is None:
            f = self.forward.device_solve_fn(engine)
            g = self.backward.device_solve_fn(engine)

            def fn(r):
                return g(f(r))

            self._device_fns[key] = fn
        return fn

    def jax_apply(self, r, *, engine=None):
        """z = M^-1 r as a traceable JAX computation (device_apply)."""
        return self.device_apply(engine)(r)

    def __call__(self, r):
        """Dispatch on the input: JAX arrays/tracers route through
        jax_apply (jit-safe), numpy through the host path."""
        try:
            import jax
            is_jax = isinstance(r, jax.Array) or isinstance(
                r, jax.core.Tracer)
        except ModuleNotFoundError:         # pragma: no cover
            is_jax = False
        if is_jax:
            return self.jax_apply(r)
        return self.apply(np.asarray(r))

    def stats(self) -> dict:
        """Merged factorization + per-operator solve stats.

        The forward/backward counters tick on HOST `apply()`/solve calls
        only; applications through the traced `device_apply` pipeline
        (the Krylov hot path) execute inside jitted programs where host
        counters cannot observe them.
        """
        return {
            "kind": self.factors.kind,
            "n": self.n,
            "nnz_L": self.factors.L.nnz,
            "nnz_U": (self.factors.U.nnz if self.factors.U is not None
                      else None),
            "shift": self.factors.shift,
            "factor_attempts": self.factors.attempts,
            "strategy": self.strategy,
            "forward": self.forward.stats.to_dict(),
            "backward": self.backward.stats.to_dict(),
        }

    def __repr__(self) -> str:  # pragma: no cover
        return (f"Preconditioner(kind={self.factors.kind!r}, n={self.n}, "
                f"strategy={self.strategy!r}, shift={self.factors.shift})")


class IdentityPreconditioner:
    """M = I — the no-preconditioning baseline with the same interface
    (handy for apples-to-apples iteration counts in benchmarks/tests)."""

    def apply(self, r):
        return np.asarray(r)

    def __call__(self, r):
        return r

    def stats(self) -> dict:
        return {"kind": "identity"}
